"""SPMD resize-correctness checks for the elastic runtime (§4.x adaptivity).

Executed as a SUBPROCESS by tests/test_runtime.py with 8 placeholder host
devices (same pattern as spmd_checks.py).  Proves the acceptance criterion:
for S2, S3, and S4, a stream processed with mid-stream parallelism-degree
changes (grow AND shrink) produces outputs and final state identical to the
fixed-degree ``reference()`` oracle — bit-exact, since all test functions
are integer or exact-min arithmetic.  Also drills the supervisor's
failure->shrink / recovery->grow path and the compiled-step cache.
"""

import os
import shutil

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", "")
)

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import patterns  # noqa: E402
from repro.runtime import (  # noqa: E402
    AccumulatorAdapter,
    Autoscaler,
    FailurePlan,
    PartitionedAdapter,
    QueueDepthPolicy,
    SeparateAdapter,
    StreamExecutor,
    SuccessiveAdapter,
    Supervisor,
)

CHUNK = 16
NUM_CHUNKS = 8


def chunks_of(xs):
    return [xs[i : i + CHUNK] for i in range(0, len(xs), CHUNK)]


# grow 2->4->8 then shrink back to 2 mid-stream
SCHEDULE = {2: 4, 4: 8, 6: 2}


def check_s2_partitioned_resize():
    num_slots = 16
    pat = patterns.PartitionedState(
        f=lambda x, s: x * 2 + s,
        ns=lambda x, s: s + x,
        h=lambda x: (x.astype(jnp.int32) * 7) % num_slots,
        num_slots=num_slots,
    )
    xs = jnp.arange(CHUNK * NUM_CHUNKS, dtype=jnp.int32)
    v0 = jnp.zeros((num_slots,), dtype=jnp.int32)

    ex = StreamExecutor(PartitionedAdapter(pat, v0), degree=2, chunk_size=CHUNK)
    outs = ex.run(chunks_of(xs), schedule=SCHEDULE)

    ys_ref, v_ref = pat.reference(xs, v0)
    got = np.concatenate([np.asarray(o) for o in outs])
    np.testing.assert_array_equal(got, np.asarray(ys_ref))
    np.testing.assert_array_equal(np.asarray(ex.state), np.asarray(v_ref))
    # resize accounting: three §4.2 block-handoff events with exact volumes
    assert [r.protocol for r in ex.metrics.resizes] == ["S2-block-handoff"] * 3
    assert [r.handoff_items for r in ex.metrics.resizes] == [
        patterns.PartitionedState.handoff_volume(num_slots, a, b)
        for a, b in ((2, 4), (4, 8), (8, 2))
    ]
    print("S2 resize ok")


def check_s2_slotmap_resize():
    """Slot-map ownership: degrees that do NOT divide num_slots (4, 5 over
    18) run and resize bit-exactly, with the slot-map handoff accounting."""
    num_slots = 18
    pat = patterns.PartitionedState(
        f=lambda x, s: x * 3 + s,
        ns=lambda x, s: s + 2 * x,
        h=lambda x: (x.astype(jnp.int32) * 11) % num_slots,
        num_slots=num_slots,
        ownership="slotmap",
    )
    chunk = 20  # divisible by 2, 4, 5 — none of which divide 18 except 2
    xs = jnp.arange(chunk * NUM_CHUNKS, dtype=jnp.int32)
    v0 = jnp.zeros((num_slots,), dtype=jnp.int32)

    ex = StreamExecutor(PartitionedAdapter(pat, v0), degree=2, chunk_size=chunk)
    outs = ex.run(
        [xs[i : i + chunk] for i in range(0, len(xs), chunk)],
        schedule={2: 4, 4: 5, 6: 2},
    )
    ys_ref, v_ref = pat.reference(xs, v0)
    got = np.concatenate([np.asarray(o) for o in outs])
    np.testing.assert_array_equal(got, np.asarray(ys_ref))
    np.testing.assert_array_equal(np.asarray(ex.state), np.asarray(v_ref))
    assert [r.protocol for r in ex.metrics.resizes] == \
        ["S2-slotmap-handoff"] * 3
    assert [r.handoff_items for r in ex.metrics.resizes] == [
        pat.transition_volume(a, b) for a, b in ((2, 4), (4, 5), (5, 2))
    ]
    print("S2 slotmap resize ok (non-divisor degrees)")


def check_s3_accumulator_resize():
    # f reads only the item (view-independent) so per-item outputs are
    # degree-invariant; the final state is exact by assoc+comm regardless.
    pat = patterns.AccumulatorState(
        f=lambda x, view: x * 3 - 1,
        g=lambda x: x,
        combine=lambda a, b: a + b,
        zero=lambda: jnp.int32(0),
    )
    xs = jnp.arange(1, CHUNK * NUM_CHUNKS + 1, dtype=jnp.int32)

    ex = StreamExecutor(
        AccumulatorAdapter(pat, flush_every=2), degree=2, chunk_size=CHUNK
    )
    outs = ex.run(chunks_of(xs), schedule=SCHEDULE)

    ys_ref, s_ref = pat.reference(xs)
    got = np.concatenate([np.asarray(o) for o in outs])
    np.testing.assert_array_equal(got, np.asarray(ys_ref))
    assert int(ex.state) == int(s_ref), (int(ex.state), int(s_ref))
    protos = [r.protocol for r in ex.metrics.resizes]
    assert protos == ["S3-identity-init", "S3-identity-init", "S3-merge"], protos
    print("S3 resize ok")


def check_s3_state_threading():
    """s0 threading: chunk N+1's views include chunk N's commits (run a
    view-reading f at fixed degree and compare to one whole-stream run)."""
    pat = patterns.AccumulatorState(
        f=lambda x, view: view,
        g=lambda x: x,
        combine=lambda a, b: a + b,
        zero=lambda: jnp.int32(0),
    )
    xs = jnp.arange(1, 33, dtype=jnp.int32)
    ex = StreamExecutor(AccumulatorAdapter(pat, flush_every=4), degree=2,
                        chunk_size=16)
    chunked = ex.run([xs[i : i + 16] for i in range(0, 32, 16)])
    whole_ys, whole_s = pat.run(
        jax.make_mesh((2,), ("workers",),
                      axis_types=(jax.sharding.AxisType.Auto,)),
        "workers", xs, flush_every=4,
    )
    # NOTE: chunked views flush MORE often at chunk boundaries than one whole
    # run with the same flush period would between chunks — the final states
    # must agree exactly, the (stale) views need not.
    assert int(ex.state) == int(whole_s) == int(jnp.sum(xs))
    print("S3 state threading ok")


def check_s4_successive_resize():
    pat = patterns.SuccessiveApproximationState(
        c=lambda x, s: x < s,
        s_prime=lambda x, s: jnp.minimum(x, s),
        direction="min",
    )
    rng = np.random.default_rng(7)
    data = rng.integers(0, 1_000_000, size=CHUNK * NUM_CHUNKS)
    xs = jnp.asarray(data, dtype=jnp.int32)

    ex = StreamExecutor(
        SuccessiveAdapter(pat, jnp.int32(2_000_000), sync_every=2),
        degree=2,
        chunk_size=CHUNK,
    )
    outs = ex.run(chunks_of(xs), schedule=SCHEDULE)

    # oracle: serial fold; committed value after chunk k is the running min
    # over everything seen so far — degree-invariant because min is exact.
    running = 2_000_000
    for k, out in enumerate(outs):
        running = min(running, int(data[: (k + 1) * CHUNK].min()))
        assert int(out["committed"]) == running, (k, int(out["committed"]), running)
    _, s_ref = pat.reference(xs, jnp.int32(2_000_000))
    assert int(ex.state) == int(s_ref) == int(data.min())
    assert all(r.protocol == "S4-global-join" for r in ex.metrics.resizes)
    print("S4 resize ok")


def check_s5_separate_resize():
    pat = patterns.SeparateTaskState(
        f=lambda x: x * x,
        s=lambda y, s: s * 31 + y,  # non-commutative: order must be canonical
    )
    xs = jnp.arange(CHUNK * NUM_CHUNKS, dtype=jnp.int32)
    ex = StreamExecutor(SeparateAdapter(pat, jnp.int32(1)), degree=2,
                        chunk_size=CHUNK)
    outs = ex.run(chunks_of(xs), schedule=SCHEDULE)
    ys_ref, trace_ref, s_ref = pat.reference(xs, jnp.int32(1))
    got = np.concatenate([np.asarray(o["ys"]) for o in outs])
    np.testing.assert_array_equal(got, np.asarray(ys_ref))
    assert int(ex.state) == int(s_ref)
    assert all(r.protocol == "S5-noop" for r in ex.metrics.resizes)
    print("S5 resize ok")


def check_compiled_step_cache():
    """Resizing back to an old degree must reuse the cached compiled step."""
    pat = patterns.SeparateTaskState(f=lambda x: x + 1, s=lambda y, s: s + y)
    ex = StreamExecutor(SeparateAdapter(pat, jnp.int32(0)), degree=2,
                        chunk_size=CHUNK)
    xs = jnp.arange(CHUNK, dtype=jnp.int32)
    ex.process(xs)
    step2 = ex._steps[2]
    ex.set_degree(4, reason="test")
    ex.process(xs)
    ex.set_degree(2, reason="test")
    assert ex._steps[2] is step2  # same jitted callable: no re-trace
    ex.process(xs)
    assert ex.compiled_degrees == [2, 4]
    print("compiled-step cache ok")


def check_autoscaler_online():
    """Queue-depth policy grows under backlog and shrinks when drained, and
    the resized run still matches the oracle bit-exactly."""
    from repro.runtime import BackpressureQueue, BoundedSource, Chunker, ConstantRate, pump

    num_slots = 16
    pat = patterns.PartitionedState(
        f=lambda x, s: x + 3 * s,
        ns=lambda x, s: s + 2 * x,
        h=lambda x: (x.astype(jnp.int32) * 13) % num_slots,
        num_slots=num_slots,
    )
    data = np.arange(CHUNK * 12, dtype=np.int32)
    v0 = jnp.zeros((num_slots,), dtype=jnp.int32)
    ex = StreamExecutor(PartitionedAdapter(pat, v0), degree=2, chunk_size=CHUNK)
    scaler = Autoscaler(
        QueueDepthPolicy(), candidates=[2, 4, 8], cooldown_chunks=1
    )
    src = BoundedSource(data)
    q = BackpressureQueue(capacity=6 * CHUNK, high_watermark=3 * CHUNK,
                          low_watermark=CHUNK // 2)
    chunker = Chunker(CHUNK)
    outs, pend, t = [], None, 0
    while not (src.exhausted and q.depth == 0):
        # heavy arrivals early (backlog builds), then the source drains
        pend = pump(src, ConstantRate(3 * CHUNK), q, t, pending=pend)
        q.observe()
        while chunker.ready(q):
            scaler.maybe_scale(ex, queue=q)  # decide on pre-take depth
            c = chunker.next_chunk(q)
            outs.append(ex.process(c, queue_depth=q.depth))
        t += 1
    ys_ref, v_ref = pat.reference(jnp.asarray(data), v0)
    got = np.concatenate([np.asarray(o) for o in outs])
    np.testing.assert_array_equal(got, np.asarray(ys_ref))
    np.testing.assert_array_equal(np.asarray(ex.state), np.asarray(v_ref))
    assert len(ex.metrics.resizes) >= 1, "backlog never triggered a resize"
    assert any(r.n_new > r.n_old for r in ex.metrics.resizes), "no grow event"
    print(f"autoscaler online ok ({len(ex.metrics.resizes)} resizes, "
          f"final degree {ex.degree})")


def check_supervisor_failure_recovery():
    pat = patterns.AccumulatorState(
        f=lambda x, view: x,
        g=lambda x: x,
        combine=lambda a, b: a + b,
        zero=lambda: jnp.int32(0),
    )
    data = np.arange(1, CHUNK * 6 + 1, dtype=np.int32)

    def chunk_fn(i):
        return jnp.asarray(data[i * CHUNK : (i + 1) * CHUNK])

    ckpt_dir = "/tmp/repro_runtime_supervisor_test"
    shutil.rmtree(ckpt_dir, ignore_errors=True)
    ex = StreamExecutor(AccumulatorAdapter(pat, flush_every=4), degree=4,
                        chunk_size=CHUNK)
    sup = Supervisor(
        ex, chunk_fn, num_chunks=6, ckpt_dir=ckpt_dir, ckpt_every=2,
        failure_plan=FailurePlan(fail_at=3, recover_after=2),
    )
    outs = sup.run()
    assert sorted(outs) == list(range(6))
    got = np.concatenate([np.asarray(outs[i]) for i in range(6)])
    ys_ref, s_ref = pat.reference(jnp.asarray(data))
    np.testing.assert_array_equal(got, np.asarray(ys_ref))
    assert int(ex.state) == int(s_ref)
    kinds = [e.kind for e in sup.events]
    assert "failure" in kinds and "shrink" in kinds and "grow" in kinds, kinds
    shutil.rmtree(ckpt_dir, ignore_errors=True)
    print("supervisor failure/recovery ok")


if __name__ == "__main__":
    assert jax.device_count() == 8, jax.devices()
    check_s2_partitioned_resize()
    check_s2_slotmap_resize()
    check_s3_accumulator_resize()
    check_s3_state_threading()
    check_s4_successive_resize()
    check_s5_separate_resize()
    check_compiled_step_cache()
    check_autoscaler_online()
    check_supervisor_failure_recovery()
    print("ALL RUNTIME CHECKS PASSED")
