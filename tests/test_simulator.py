"""Tests of the discrete-event farm simulator against the paper's analytic
models (§2 service time, eq. (1) speedup bound, eq. (2) ideal completion)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import analytics, simulator


class TestSerial:
    def test_completion_is_m_times_tf_plus_ts(self):
        r = simulator.simulate_serial(100, t_f=2.0, t_s=1.0)
        assert r.completion_time == pytest.approx(300.0)


class TestPartitioned:
    def test_fair_hash_near_ideal(self):
        m, t_f = 1024, 1.0
        for n_w in (2, 4, 8, 16):
            r = simulator.simulate_partitioned(m, n_w, t_f, 0.0)
            assert r.completion_time == pytest.approx(m * t_f / n_w)

    def test_skewed_hash_impairs_speedup(self):
        m = 4096
        fair = simulator.simulate_partitioned(m, 8, 1.0, 0.0, skew=0.0)
        skewed = simulator.simulate_partitioned(m, 8, 1.0, 0.0, skew=1.5, seed=1)
        assert skewed.completion_time > 1.5 * fair.completion_time

    @given(st.integers(min_value=1, max_value=32))
    @settings(max_examples=20, deadline=None)
    def test_never_faster_than_ideal(self, n_w):
        m = 256
        r = simulator.simulate_partitioned(m, n_w, 1.0, 0.5, skew=0.7, seed=3)
        ideal = analytics.ideal_completion(m, 1.0, 0.5, n_w)
        assert r.completion_time >= ideal - 1e-9


class TestAccumulator:
    def test_tf_much_larger_than_ts_scales_ideally(self):
        """Paper Fig. 3: t_f = 100 t_acc => completion ~ ideal eq. (2)."""
        m, t_f, t_acc = 2048, 100.0, 1.0
        for n_w in (1, 2, 4, 8, 16):
            r = simulator.simulate_accumulator(m, n_w, t_f, t_acc, flush_every=1)
            ideal = analytics.ideal_completion(m, t_f, t_acc, n_w)
            assert r.completion_time <= ideal * 1.05

    def test_frequent_updates_saturate_collector(self):
        """Paper Fig. 4: t_f = 2 t_acc and flush_every=1 stops scaling early;
        larger flush periods restore scalability."""
        m, t_f, t_acc = 4096, 2.0, 1.0
        freq1 = [
            simulator.simulate_accumulator(m, n, t_f, t_acc, flush_every=1)
            for n in (4, 16, 32)
        ]
        # collector work m*t_acc = 4096 lower-bounds completion
        assert freq1[-1].completion_time >= m * t_acc
        freq64 = simulator.simulate_accumulator(m, 32, t_f, t_acc, flush_every=64)
        ideal = analytics.ideal_completion(m, t_f, t_acc, 32)
        assert freq64.completion_time <= ideal * 1.10
        assert freq64.completion_time < freq1[-1].completion_time / 2

    def test_flush_threshold_rule(self):
        """The queueing form of the paper's flush-period rule demarcates the
        scaling/saturated regimes."""
        m, t_f, t_acc, n_w = 8192, 1.0, 1.0, 16
        k_stable = analytics.stable_flush_period(t_f, t_acc, n_w)  # = 16
        good = simulator.simulate_accumulator(
            m, n_w, t_f, t_acc, flush_every=int(4 * k_stable)
        )
        bad = simulator.simulate_accumulator(
            m, n_w, t_f, t_acc, flush_every=max(1, int(k_stable // 4))
        )
        ideal = analytics.ideal_completion(m, t_f, t_acc, n_w)
        assert good.completion_time <= ideal * 1.10
        assert bad.completion_time >= ideal * 1.5

    def test_update_count(self):
        r = simulator.simulate_accumulator(100, 4, 1.0, 0.1, flush_every=10)
        assert 10 <= r.state_updates_sent <= 14  # 10 full + <=4 residual


class TestSuccessiveApproximation:
    def test_larger_tc_scales_better(self):
        """Paper Fig. 5: larger condition-evaluation time => better scaling."""
        m, n_w = 2048, 16
        heavy = simulator.simulate_successive_approximation(
            m, n_w, t_c=100.0, t_s=1.0, seed=0
        )
        light = simulator.simulate_successive_approximation(
            m, n_w, t_c=1.0, t_s=100.0, seed=0
        )
        ideal_heavy = analytics.ideal_completion(m, 100.0, 0.0, n_w)
        assert heavy.completion_time <= ideal_heavy * 1.2
        # efficiency vs its own serial run
        ser_h = simulator.simulate_successive_approximation(m, 1, 100.0, 1.0, seed=0)
        ser_l = simulator.simulate_successive_approximation(m, 1, 1.0, 100.0, seed=0)
        eff_h = ser_h.completion_time / (n_w * heavy.completion_time)
        eff_l = ser_l.completion_time / (n_w * light.completion_time)
        assert eff_h > eff_l

    def test_staleness_causes_discarded_updates(self):
        m, n_w = 4096, 32
        fresh = simulator.simulate_successive_approximation(
            m, n_w, 1.0, 1.0, feedback_latency=0.0, seed=0
        )
        stale = simulator.simulate_successive_approximation(
            m, n_w, 1.0, 1.0, feedback_latency=500.0, seed=0
        )
        assert stale.state_updates_sent >= fresh.state_updates_sent
        assert stale.state_updates_discarded >= fresh.state_updates_discarded

    def test_monotone_accept_only(self):
        r = simulator.simulate_successive_approximation(512, 8, 1.0, 1.0, seed=7)
        accepted = r.state_updates_sent - r.state_updates_discarded
        assert accepted >= 1  # the global minimum is always accepted


class TestSeparateTaskState:
    @given(st.sampled_from([1, 2, 4, 8, 16, 32, 64]))
    @settings(max_examples=10, deadline=None)
    def test_speedup_bounded_by_eq1(self, n_w):
        """Paper Figs. 6/7: speedup saturates at t_f/t_s + 1."""
        m, t_f, t_s = 4096, 10.0, 1.0
        ser = simulator.simulate_serial(m, t_f, t_s).completion_time
        par = simulator.simulate_separate_task_state(m, n_w, t_f, t_s).completion_time
        speedup = ser / par
        assert speedup <= analytics.separate_speedup_bound(t_f, t_s) + 1e-6
        assert speedup <= n_w + 1e-6
        # the paper's finite-n_w model (all updates serialized after one t_f)
        # is a conservative floor; the pipelined farm does at least that well
        assert speedup >= analytics.separate_speedup(n_w, t_f, t_s) * 0.95

    def test_case_A_B_C_bounds(self):
        """The paper's three cases: bounds 101, 11, 6."""
        for ratio, bound in ((100.0, 101.0), (10.0, 11.0), (5.0, 6.0)):
            ser = simulator.simulate_serial(8192, ratio, 1.0).completion_time
            par = simulator.simulate_separate_task_state(
                8192, 256, ratio, 1.0
            ).completion_time
            assert ser / par <= bound + 1e-6
            assert ser / par >= bound * 0.85  # saturates close to the bound


class TestAnalytics:
    def test_service_time(self):
        assert analytics.service_time(0.5, 8.0, 4) == 2.0
        assert analytics.service_time(3.0, 8.0, 4) == 3.0

    def test_flush_rules_coincide_when_tf_eq_tacc(self):
        assert analytics.paper_flush_threshold(1.0, 1.0, 16) == pytest.approx(
            analytics.stable_flush_period(1.0, 1.0, 16)
        )

    def test_roofline_terms(self):
        r = analytics.Roofline(
            flops=1e15, hbm_bytes=1e12, collective_bytes=1e11, chips=256
        )
        assert r.compute_s == pytest.approx(1e15 / (256 * 197e12))
        assert r.memory_s == pytest.approx(1e12 / (256 * 819e9))
        assert r.collective_s == pytest.approx(1e11 / (256 * 50e9))
        assert r.dominant in ("compute", "memory", "collective")
        assert r.step_time == max(r.compute_s, r.memory_s, r.collective_s)
        assert 0 < r.mfu_upper_bound(0.5e15) <= 1.0 / r.step_time * 0.5e15
