"""Tests for the closed-loop SLO plane: burn-rate objectives
(repro.obs.slo), online stage-regression detection (repro.obs.detect), the
SLO-driven autoscaling policy, and the flight-recorder black box."""

import json

import pytest

from repro.obs import (
    FLIGHT_RECORDER,
    FlightRecorder,
    Histogram,
    LogicalClock,
    MetricsRegistry,
    Tracer,
)
from repro.obs.detect import RegressionDetector, StageBaseline
from repro.obs.slo import SLOEngine, SLOSpec, SLOTracker
from repro.runtime.autoscaler import Autoscaler, SLOLatencyPolicy
from repro.runtime.metrics import ChunkRecord, MetricsBus


# ---------------------------------------------------------------------------
# SLO spec + tracker
# ---------------------------------------------------------------------------

class TestSLOSpec:
    def test_validation(self):
        with pytest.raises(ValueError):
            SLOSpec(name="x", objective=0.0)
        with pytest.raises(ValueError):
            SLOSpec(name="x", objective=1.0, compliance=1.0)
        with pytest.raises(ValueError):
            SLOSpec(name="x", objective=1.0, q=0.0)
        with pytest.raises(ValueError):
            SLOSpec(name="x", objective=1.0, short_window=8, long_window=4)
        with pytest.raises(ValueError):
            SLOSpec(name="x", objective=1.0, fast_burn=0.5, slow_burn=1.0)
        with pytest.raises(ValueError):
            SLOSpec(name="x", objective=1.0, fast_burn=2.0, slow_burn=0.0)

    def test_budget(self):
        assert SLOSpec(name="x", objective=1.0,
                       compliance=0.99).budget == pytest.approx(0.01)


class TestSLOTracker:
    def _spec(self, **kw):
        base = dict(name="t", objective=1.0, compliance=0.9,
                    short_window=4, long_window=16,
                    fast_burn=2.0, slow_burn=1.0)
        base.update(kw)
        return SLOSpec(**base)

    def test_burn_rate_math(self):
        tr = SLOTracker(self._spec())
        for v in (0.5, 0.5, 2.0, 2.0):  # 2 bad of 4, budget 0.1
            tr.observe(v)
        assert tr.burn_rate(4) == pytest.approx((2 / 4) / 0.1)
        assert tr.burn_rate(2) == pytest.approx((2 / 2) / 0.1)

    def test_budget_remaining_lifetime(self):
        tr = SLOTracker(self._spec())
        for _ in range(95):
            tr.observe(0.5)
        for _ in range(5):
            tr.observe(2.0)  # 5% bad against a 10% budget -> half left
        assert tr.budget_remaining() == pytest.approx(0.5)

    def test_verdict_transitions_emit_instants_once(self):
        clk = LogicalClock()
        tracer = Tracer(clock=clk, recorder=None)
        tr = SLOTracker(self._spec(), tracer=tracer)
        for _ in range(8):
            tr.observe(2.0)
            tr.evaluate()
        assert tr.evaluate().verdict == "breach"
        assert tr.breaches == 1
        names = [i.name for i in tracer.instants]
        # one transition instant, not one per evaluation
        assert names.count("slo.breach") == 1
        for _ in range(64):
            tr.observe(0.5)
        final = tr.evaluate()
        assert final.verdict == "ok"
        assert [i.name for i in tracer.instants].count("slo.ok") == 1

    def test_single_slow_sample_cannot_breach(self):
        tr = SLOTracker(self._spec())
        for _ in range(15):
            tr.observe(0.5)
        tr.observe(5.0)
        # short window burns (1/4 / 0.1 = 2.5 >= 2) but the long window
        # (1/16 / 0.1 = 0.625 < 1) vetoes: no page from one slow chunk
        assert tr.evaluate().verdict == "ok"

    def test_histogram_diff_ingest(self):
        h = Histogram(lo=1e-3, hi=1e3)
        tr = SLOTracker(self._spec())
        for v in (0.5, 0.5, 0.5, 20.0):
            h.record(v)
        assert tr.ingest_histogram(h) == 4
        assert tr.total_n == 4 and tr.total_bad == 1
        # idempotent between recordings: no new samples, no new ticks
        assert tr.ingest_histogram(h) == 0
        h.record(50.0)
        assert tr.ingest_histogram(h) == 1
        assert tr.total_bad == 2

    def test_throughput_floor(self):
        tr = SLOTracker(self._spec(throughput_floor=100.0))
        tr.observe(0.5)
        assert tr.evaluate(throughput=200.0).verdict == "ok"
        assert tr.evaluate(throughput=50.0).verdict == "breach"

    def test_percentile_prefers_exact_window(self):
        tr = SLOTracker(self._spec(q=0.5))
        for v in (1.0, 2.0, 3.0):
            tr.observe(v)
        assert tr.percentile() == pytest.approx(2.0)


class TestSLOEngine:
    def test_add_evaluate_export(self):
        eng = SLOEngine()
        tr = eng.add(SLOSpec(name="lat", objective=1.0, compliance=0.9,
                             short_window=2, long_window=4))
        with pytest.raises(ValueError):
            eng.add(SLOSpec(name="lat", objective=2.0))
        for _ in range(4):
            tr.observe(5.0)
        statuses = eng.evaluate_all()
        assert statuses["lat"].verdict == "breach"
        reg = MetricsRegistry()
        eng.export(reg)
        snap = reg.snapshot()
        assert snap["gauges"]["slo.lat.objective"] == 1.0
        assert snap["gauges"]["slo.lat.burn_short"] == pytest.approx(10.0)
        assert snap["counters"]["slo.lat.breaches"] == 1
        assert eng.snapshot()["lat"]["verdict"] == "breach"
        assert eng["lat"] is tr


# ---------------------------------------------------------------------------
# stage-regression detection
# ---------------------------------------------------------------------------

class TestStageBaseline:
    def test_median_mad_sigma(self):
        b = StageBaseline(window=16, min_samples=4)
        for d in (1.0, 1.1, 0.9, 1.0, 1.0):
            b.add(d)
        assert b.ready
        assert b.median() == pytest.approx(1.0)
        assert b.mad() == pytest.approx(0.0)
        # MAD of 0 falls back to the relative floor, not a zero sigma
        assert b.sigma() == pytest.approx(0.05 * 1.0)
        z, factor = b.score(2.0)
        assert factor == pytest.approx(2.0)
        assert z == pytest.approx(1.0 / 0.05)

    def test_not_ready_below_min_samples(self):
        b = StageBaseline(min_samples=8)
        for _ in range(7):
            b.add(1.0)
        assert not b.ready


def _emit_chunk(tracer, clk, stage_durs):
    with tracer.span("chunk"):
        for name, d in stage_durs.items():
            with tracer.span(name):
                clk.advance(d)


class TestRegressionDetector:
    STAGES = ("s1", "s2")

    def test_validation(self):
        tracer = Tracer(recorder=None)
        with pytest.raises(ValueError):
            RegressionDetector(tracer, min_samples=0)
        with pytest.raises(ValueError):
            RegressionDetector(tracer, window=4, min_samples=8)

    def _run(self, detector, tracer, clk, n, durs):
        out = []
        for _ in range(n):
            _emit_chunk(tracer, clk, durs)
            out.extend(detector.consume())
        return out

    def test_detects_and_attributes_injected_stage(self):
        clk = LogicalClock()
        tracer = Tracer(clock=clk, recorder=None)
        reg = MetricsRegistry()
        det = RegressionDetector(tracer, stages=self.STAGES, min_samples=8,
                                 registry=reg)
        base = {"s1": 1.0, "s2": 0.5}
        assert self._run(det, tracer, clk, 12, base) == []
        flagged = self._run(det, tracer, clk, 3, {"s1": 1.0, "s2": 2.5})
        assert flagged
        first = flagged[0]
        assert first.stage == "s2"
        assert first.stage_factor == pytest.approx(5.0)
        assert first.chunk == 12
        assert any(i.name == "detect.regression" for i in tracer.instants)
        assert reg.counter("obs.detect.regressions").value == len(flagged)

    def test_no_false_positives_on_steady_stream(self):
        clk = LogicalClock()
        tracer = Tracer(clock=clk, recorder=None)
        det = RegressionDetector(tracer, stages=self.STAGES, min_samples=8)
        assert self._run(det, tracer, clk, 40, {"s1": 1.0, "s2": 0.5}) == []

    def test_incremental_consume_equivalent(self):
        def run(consume_every):
            clk = LogicalClock()
            tracer = Tracer(clock=clk, recorder=None)
            det = RegressionDetector(tracer, stages=self.STAGES,
                                     min_samples=8)
            out = []
            for i in range(16):
                durs = ({"s1": 1.0, "s2": 0.5} if i < 12
                        else {"s1": 3.0, "s2": 0.5})
                _emit_chunk(tracer, clk, durs)
                if i % consume_every == consume_every - 1:
                    out.extend(det.consume())
            out.extend(det.consume())
            return [(r.chunk, r.stage) for r in out]

        assert run(1) == run(4) != []

    def test_unattributed_when_no_stage_breaches(self):
        clk = LogicalClock()
        tracer = Tracer(clock=clk, recorder=None)
        det = RegressionDetector(tracer, stages=self.STAGES, min_samples=8)
        self._run(det, tracer, clk, 12, {"s1": 1.0, "s2": 0.5})
        # chunk-level slowdown spread thinly across untracked time: both
        # stages nudge up below their own thresholds while the chunk doubles
        with tracer.span("chunk"):
            with tracer.span("s1"):
                clk.advance(1.2)
            with tracer.span("s2"):
                clk.advance(0.6)
            clk.advance(1.5)  # untracked tail
        flagged = det.consume()
        assert len(flagged) == 1
        assert flagged[0].stage is None

    def test_baselines_absorb_sustained_shift(self):
        clk = LogicalClock()
        tracer = Tracer(clock=clk, recorder=None)
        det = RegressionDetector(tracer, stages=self.STAGES, window=8,
                                 min_samples=4)
        self._run(det, tracer, clk, 8, {"s1": 1.0, "s2": 0.5})
        flagged = self._run(det, tracer, clk, 20, {"s1": 4.0, "s2": 0.5})
        # flagged at the change, then absorbed as the new normal
        assert flagged
        assert all(r.chunk < 8 + 10 for r in flagged)


# ---------------------------------------------------------------------------
# SLO-driven autoscaling
# ---------------------------------------------------------------------------

def _modeled_bus(clk, *, work, degree, chunks, m=64):
    bus = MetricsBus(clock=clk)
    for _ in range(chunks):
        t0 = clk.now()
        clk.advance(work / degree)
        bus.record_chunk(ChunkRecord(t0, clk.now(), m=m, n_workers=degree,
                                     queue_depth=0))
    return bus


class TestSLOLatencyPolicy:
    CANDIDATES = (1, 2, 4, 8, 16)

    def test_shrinks_overprovisioned_to_smallest_fit(self):
        clk = LogicalClock()
        bus = _modeled_bus(clk, work=256.0, degree=16, chunks=8)
        pol = SLOLatencyPolicy(objective=70.0)
        # work 256: 256/4 = 64 <= 70 but 256/2 = 128 > 70 -> smallest fit 4
        assert pol.target(bus, 16, self.CANDIDATES) == 4
        assert "smallest modeled fit" in pol.last_signal

    def test_grows_on_load_shift(self):
        clk = LogicalClock()
        bus = _modeled_bus(clk, work=768.0, degree=4, chunks=8)
        pol = SLOLatencyPolicy(objective=70.0)
        assert pol.target(bus, 4, self.CANDIDATES) == 16

    def test_burn_breach_overrides_model(self):
        clk = LogicalClock()
        bus = _modeled_bus(clk, work=256.0, degree=4, chunks=8)
        tracker = SLOTracker(SLOSpec(
            name="x", objective=70.0, compliance=0.9,
            short_window=2, long_window=4, fast_burn=2.0, slow_burn=1.0))
        for _ in range(4):
            tracker.observe(200.0)  # external evidence the budget is burning
        pol = SLOLatencyPolicy(objective=70.0, tracker=tracker)
        # the model says 4 fits, the burn rate says step up anyway
        assert pol.target(bus, 4, self.CANDIDATES) == 8
        assert "burn-rate breach overrides model" in pol.last_signal

    def test_autoscaler_converges_through_hysteresis(self):
        clk = LogicalClock()
        pol = SLOLatencyPolicy(objective=70.0, window=8)
        asc = Autoscaler(pol, self.CANDIDATES, cooldown_chunks=1, confirm=2)
        bus = MetricsBus(clock=clk)
        degree = 16
        seen = []
        for _ in range(10):
            target = asc.propose(bus, degree)
            asc.tick()
            if target is not None:
                degree = target
                asc.notify_resized()
            t0 = clk.now()
            clk.advance(256.0 / degree)
            bus.record_chunk(ChunkRecord(t0, clk.now(), m=64,
                                         n_workers=degree, queue_depth=0))
            seen.append(degree)
        assert seen[-1] == 4
        assert all(d == 4 for d in seen[4:])

    def test_serving_mode_steps_down_on_breach(self):
        clk = LogicalClock()
        bus = _modeled_bus(clk, work=8.0, degree=1, chunks=6)  # 8.0 ticks
        pol = SLOLatencyPolicy(objective=2.0, mode="serving")
        assert pol.target(bus, 8, self.CANDIDATES) == 4

    def test_decision_carries_signal(self):
        d_fields = {f.name for f in
                    __import__("dataclasses").fields(
                        __import__("repro.runtime.autoscaler",
                                   fromlist=["Decision"]).Decision)}
        assert "signal" in d_fields


# ---------------------------------------------------------------------------
# flight recorder
# ---------------------------------------------------------------------------

class TestFlightRecorder:
    def test_ring_keeps_newest_when_buffer_keeps_oldest(self):
        clk = LogicalClock()
        ring = FlightRecorder(capacity=4)
        tracer = Tracer(clock=clk, max_events=2, recorder=ring)
        for name in "abcdef":
            with tracer.span(name):
                clk.advance(1.0)
        assert [s.name for s in tracer.spans] == ["a", "b"]      # oldest
        assert [s.name for s in ring.spans] == list("cdef")      # newest
        assert tracer.dropped_spans == 4

    def test_default_tracer_feeds_global_recorder(self):
        FLIGHT_RECORDER.reset()
        clk = LogicalClock()
        tracer = Tracer(clock=clk)
        assert tracer.recorder is FLIGHT_RECORDER
        with tracer.span("s"):
            clk.advance(1.0)
        assert len(FLIGHT_RECORDER) >= 1
        FLIGHT_RECORDER.reset()
        # opting out severs the feed
        t2 = Tracer(clock=clk, recorder=None)
        with t2.span("s"):
            clk.advance(1.0)
        assert len(FLIGHT_RECORDER) == 0

    def test_dump_is_loadable_chrome_trace(self, tmp_path):
        clk = LogicalClock()
        ring = FlightRecorder(capacity=8, metrics_capacity=2)
        tracer = Tracer(clock=clk, max_events=1, recorder=ring)
        with tracer.span("work"):
            clk.advance(1.0)
        tracer.instant("failure", detail="boom")
        reg = MetricsRegistry()
        reg.gauge("g").set(3.0)
        for _ in range(3):  # ring bounded at metrics_capacity
            ring.sample_metrics(reg, t=clk.now())
        assert len(ring.metrics_ring) == 2
        path = tmp_path / "bb.json"
        ring.dump(str(path), registry=reg)
        doc = json.loads(path.read_text())
        names = {e.get("name") for e in doc["traceEvents"]}
        assert {"work", "failure"} <= names
        assert doc["otherData"]["metrics_ring"]
        assert doc["otherData"]["metrics"]["gauges"]["g"] == 3.0


class TestSupervisorBlackBox:
    def test_dumps_on_failure_and_restore(self, tmp_path):
        import numpy as np

        from repro.keyed import KeyedWindowAdapter, WindowSpec
        from repro.keyed.runtime import synthetic_keyed_items
        from repro.runtime import BoundedSource, StreamExecutor
        from repro.runtime.supervisor import FailurePlan, Supervisor

        nch, ch = 6, 128
        spec = WindowSpec("tumbling", size=16, lateness=4, late_policy="side")
        items = synthetic_keyed_items(ch * nch, num_keys=32, disorder=3,
                                      seed=5)
        src = BoundedSource(items)
        ad = KeyedWindowAdapter(spec, num_slots=64, backend="device_table",
                                capacity=256)
        ring = FlightRecorder(capacity=256)
        tracer = Tracer(max_events=16, recorder=ring)  # saturates early
        ex = StreamExecutor(ad, degree=4, chunk_size=ch, tracer=tracer)
        reg = MetricsRegistry()
        sup = Supervisor(
            ex, lambda i: (src.seek(i * ch), src.take(ch))[1], nch,
            ckpt_dir=str(tmp_path / "ckpt"), ckpt_every=2,
            failure_plan=FailurePlan(fail_at=3, recover_after=2),
            blackbox_dir=str(tmp_path / "bb"), registry=reg,
        )
        outs = sup.run()
        assert len(outs) == nch
        kinds = [p.split("/")[-1].split("_")[0] for p in sup.blackbox_paths]
        assert kinds == ["failure", "restore"]
        assert tracer.dropped > 0  # the main buffer did overflow
        fail_doc = json.loads(open(sup.blackbox_paths[0]).read())
        events = fail_doc["traceEvents"]
        assert any(e.get("ph") == "i" and e.get("name") == "failure"
                   for e in events)
        # the metrics snapshot rode along
        assert "metrics_ring" in fail_doc["otherData"]
        restore_doc = json.loads(open(sup.blackbox_paths[1]).read())
        assert any(e.get("ph") == "X" and e.get("name") == "restore"
                   for e in restore_doc["traceEvents"])
        # black boxes did not perturb the run: emissions match a clean run
        ad2 = KeyedWindowAdapter(spec, num_slots=64, backend="device_table",
                                 capacity=256)
        ex2 = StreamExecutor(ad2, degree=4, chunk_size=ch)
        outs2 = {}
        for i in range(nch):
            src.seek(i * ch)
            outs2[i] = ex2.process(src.take(ch))
        for i in range(nch):
            for k in outs[i]["emissions"]:
                np.testing.assert_array_equal(
                    outs[i]["emissions"][k], outs2[i]["emissions"][k])
