"""Continuous-batching engine: batched generation == sequential oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.models import transformer as T
from repro.serving.engine import Request, ServingEngine


@pytest.fixture(scope="module")
def setup():
    cfg = configs.get("paper-synthetic").reduced()
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def sequential_generate(cfg, params, prompt, n_new, s_max=64):
    """Oracle: single-request prefill + decode loop."""
    caches = T.init_caches(cfg, 1, s_max, cfg.cdtype)
    logits, caches = T.prefill_forward(
        params, {"tokens": jnp.asarray(prompt, jnp.int32)[None, :]}, cfg, caches
    )
    out = [int(jnp.argmax(logits[:, -1], -1)[0])]
    pos = len(prompt)
    for _ in range(n_new - 1):
        logits, caches = T.decode_forward(
            params, {"tokens": jnp.asarray([[out[-1]]], jnp.int32)}, cfg,
            caches, jnp.int32(pos),
        )
        out.append(int(jnp.argmax(logits[:, -1], -1)[0]))
        pos += 1
    return out


class TestServingEngine:
    def test_continuous_batching_matches_sequential(self, setup):
        cfg, params = setup
        rng = np.random.default_rng(0)
        prompts = [rng.integers(0, 200, size=n).astype(np.int32)
                   for n in (5, 9, 5, 13)]
        n_new = 6

        engine = ServingEngine(cfg, params, num_slots=3, s_max=64)
        reqs = [Request(rid=i, prompt=p, max_new_tokens=n_new)
                for i, p in enumerate(prompts)]
        for r in reqs:
            engine.submit(r)
        engine.run_to_completion()

        for r in reqs:
            want = sequential_generate(cfg, params, r.prompt, n_new)
            assert r.generated == want, (r.rid, r.generated, want)

    def test_more_requests_than_slots_drains(self, setup):
        cfg, params = setup
        rng = np.random.default_rng(1)
        engine = ServingEngine(cfg, params, num_slots=2, s_max=64)
        reqs = [Request(rid=i, prompt=rng.integers(0, 200, size=5).astype(np.int32),
                        max_new_tokens=3) for i in range(7)]
        for r in reqs:
            engine.submit(r)
        engine.run_to_completion()
        assert all(len(r.generated) == 3 for r in reqs)
        assert engine.tokens_out == 21

    def test_hash_policy_partitioning(self, setup):
        """S2: hash assignment routes each session to its fixed slot."""
        cfg, params = setup
        rng = np.random.default_rng(2)
        engine = ServingEngine(cfg, params, num_slots=4, s_max=64, policy="hash")
        reqs = [Request(rid=i, prompt=rng.integers(0, 200, size=4).astype(np.int32),
                        max_new_tokens=2) for i in range(6)]
        for r in reqs:
            engine.submit(r)
        engine.run_to_completion()
        for r in reqs:
            assert r.slot == (r.rid * 2654435761) % 4
            assert len(r.generated) == 2

    def test_mamba_family_serving(self):
        """The engine also serves recurrent-state (SSM) models."""
        cfg = configs.get("mamba2-780m").reduced()
        params = T.init_params(cfg, jax.random.PRNGKey(1))
        rng = np.random.default_rng(3)
        engine = ServingEngine(cfg, params, num_slots=2, s_max=32)
        reqs = [Request(rid=i, prompt=rng.integers(0, 200, size=6).astype(np.int32),
                        max_new_tokens=4) for i in range(3)]
        for r in reqs:
            engine.submit(r)
        engine.run_to_completion()
        for r in reqs:
            want = sequential_generate(cfg, params, r.prompt, 4, s_max=32)
            assert r.generated == want
