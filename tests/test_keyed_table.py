"""Tests for the device-resident keyed window table (`repro.keyed.table`).

Acceptance contract (ISSUE 3): device-table runs — including **forced
eviction** (tiny TTL), **forced spill** (tiny capacity/probe budget), and
mid-stream grow/shrink at worker counts that do NOT divide ``num_slots`` —
are bit-exact against :func:`repro.core.semantics.keyed_windows`, and a
snapshot/restore through the canonical pytree replays to identical
emissions.  Plus: open-addressing invariants, the Pallas lookup kernel vs
its reference vs the numpy probe-window realization, and the resize
accounting that migrates table rows rather than dict entries.
"""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import semantics
from repro.keyed import (
    DeviceWindowTable,
    KeyedWindowAdapter,
    KeyedWindowEngine,
    WindowSpec,
    cell_hash,
    keyed_stream,
    migrated_rows,
    synthetic_keyed_items,
)
from repro.kernels import ops
from repro.runtime import FailurePlan, StreamExecutor, Supervisor

NUM_SLOTS = 20
CHUNK = 16

#: configs that force every tier transition: ample table, probe-window spill,
#: TTL eviction churn, and both at once
TABLE_CONFIGS = [
    dict(capacity=256),
    dict(capacity=16, max_probes=4),          # forced spill
    dict(capacity=64, ttl=0),                 # eviction of anything idle
    dict(capacity=8, max_probes=2, ttl=2),    # spill + eviction together
]


def _triples(items):
    return [(int(r["key"]), int(r["value"]), int(r["ts"])) for r in items]


def _emissions(outs):
    return [
        tuple(int(x) for x in row)
        for o in outs
        for row in zip(
            *(o["emissions"][k] for k in ("key", "start", "end", "value",
                                          "count"))
        )
    ]


def _state_rows(state):
    return [
        tuple(int(x) for x in r)
        for r in zip(
            *(np.asarray(state[k]).tolist()
              for k in ("w_key", "w_start", "w_end", "w_value", "w_count"))
        )
    ]


def _spec_for(kind):
    if kind == "tumbling":
        return WindowSpec("tumbling", size=7, lateness=3, late_policy="side")
    return WindowSpec("sliding", size=9, slide=4, lateness=3,
                      late_policy="side")


# ---------------------------------------------------------------------------
# cell hash + table mechanics
# ---------------------------------------------------------------------------

class TestCellHash:
    def test_scalar_array_agree_including_negative_keys(self):
        for key, start in [(-5, 0), (7, -14), (-(2 ** 40), 21), (0, 0)]:
            h = int(cell_hash(key, start, 64))
            ha = int(cell_hash(np.array([key]), np.array([start]), 64)[0])
            assert h == ha and 0 <= h < 64

    def test_start_decorrelates_cells_of_one_key(self):
        hs = cell_hash(np.zeros(32, np.int64),
                       np.arange(32, dtype=np.int64) * 7, 1024)
        assert len(np.unique(hs)) > 16  # same key, different windows spread


class TestDeviceWindowTable:
    def test_update_accumulates_and_touches(self):
        t = DeviceWindowTable(32, max_probes=4)
        ck = np.array([1, 2, 3], np.int64)
        cs = np.array([0, 0, 7], np.int64)
        assert t.update(ck, cs, cs + 7, [10, 20, 30], [1, 1, 1], 5) is None
        assert t.update(ck, cs, cs + 7, [1, 2, 3], [1, 1, 1], 9) is None
        rows = t.lookup(ck, cs)
        assert (rows >= 0).all()
        np.testing.assert_array_equal(t.value[rows], [11, 22, 33])
        np.testing.assert_array_equal(t.count[rows], [2, 2, 2])
        np.testing.assert_array_equal(t.touch[rows], [9, 9, 9])
        assert t.stats.inserted == 3 and t.stats.hits == 3

    def test_lookup_scans_past_freed_rows_no_duplicates(self):
        """Emission frees a row mid-probe-window; a later lookup of a cell
        placed beyond it must still find the live row (no tombstones, no
        duplicate claim)."""
        cap = 8
        # three cells with the SAME home slot -> consecutive probe placement
        keys = []
        k = 0
        home = int(cell_hash(0, 0, cap))
        while len(keys) < 3:
            if int(cell_hash(k, 0, cap)) == home:
                keys.append(k)
            k += 1
        ck = np.asarray(sorted(keys), np.int64)
        cs = np.zeros(3, np.int64)
        t = DeviceWindowTable(cap, max_probes=4)
        t.update(ck, cs, cs + 7, [1, 1, 1], [1, 1, 1], 0)
        rows = t.lookup(ck, cs)
        assert sorted(rows.tolist()) == [(home + i) % cap for i in range(3)]
        # free the FIRST cell's row (as emission would), then look up the rest
        t.occ[rows[0]] = False
        again = t.lookup(ck, cs)
        assert again[0] == -1
        np.testing.assert_array_equal(again[1:], rows[1:])
        # re-update must accumulate into the surviving rows, not re-claim them
        t.update(ck[1:], cs[1:], cs[1:] + 7, [5, 5], [1, 1], 1)
        assert t.value[rows[1]] == 6 and t.value[rows[2]] == 6

    def test_probe_window_exhaustion_spills(self):
        t = DeviceWindowTable(4, max_probes=2)
        ck = np.arange(8, dtype=np.int64)
        cs = np.zeros(8, np.int64)
        spill = t.update(ck, cs, cs + 7, np.ones(8), np.ones(8), 0)
        assert spill is not None
        sk = spill[0]
        assert len(sk) + t.occupancy == 8
        assert t.stats.spilled == len(sk)
        # spilled cells are exactly those absent from the table
        assert (t.lookup(sk, np.zeros(len(sk), np.int64)) == -1).all()

    def test_take_due_and_evict_idle(self):
        t = DeviceWindowTable(32, max_probes=4)
        ck = np.array([1, 2, 3], np.int64)
        cs = np.array([0, 7, 14], np.int64)
        t.update(ck, cs, cs + 7, [1, 1, 1], [1, 1, 1], touch_ts=10)
        k, s, e, v, c, _ = t.take_due(watermark=14)  # ends 7, 14 fire
        assert sorted(k.tolist()) == [1, 2] and t.occupancy == 1
        # remaining row: touched at 10, ttl 3 -> idle at wm 13
        k2, *_ = t.evict_idle(watermark=13, ttl=3)
        assert k2.tolist() == [3] and t.occupancy == 0
        assert t.stats.evicted == 1

    def test_never_touched_sentinel_handles_negative_times(self):
        t = DeviceWindowTable(8, max_probes=4)
        t.update(np.array([5]), np.array([-21]), np.array([-14]),
                 [1], [1], touch_ts=-9)
        row = int(t.lookup(np.array([5]), np.array([-21]))[0])
        assert t.touch[row] == -9  # max(sentinel, -9) == -9, not 0

    def test_insert_rows_rebuild_matches_live_placement_semantics(self):
        t = DeviceWindowTable(16, max_probes=4)
        ck = np.arange(10, dtype=np.int64)
        cs = np.zeros(10, np.int64)
        t.update(ck, cs, cs + 7, np.arange(10), np.ones(10), 3)
        rows = t.rows()
        order = np.lexsort((rows[:, 1], rows[:, 0]))  # canonical (key, start)
        rows = rows[order]
        t2 = DeviceWindowTable(16, max_probes=4)
        assert t2.insert_rows(*(rows[:, i] for i in range(6))) is None
        r1 = t.lookup(ck, cs)
        r2 = t2.lookup(ck, cs)
        assert (r2 >= 0).all()
        np.testing.assert_array_equal(t.value[r1], t2.value[r2])
        np.testing.assert_array_equal(t.touch[r1], t2.touch[r2])

    def test_bad_args(self):
        with pytest.raises(ValueError):
            DeviceWindowTable(0)
        with pytest.raises(ValueError):
            DeviceWindowTable(8, max_probes=0)
        with pytest.raises(ValueError):
            KeyedWindowEngine(
                WindowSpec("tumbling", size=4), num_slots=8, backend="gpu"
            )
        with pytest.raises(ValueError):
            KeyedWindowEngine(
                WindowSpec("tumbling", size=4), num_slots=8,
                backend="device_table", ttl=-1,
            )


# ---------------------------------------------------------------------------
# Pallas lookup kernel vs reference vs numpy probe realization
# ---------------------------------------------------------------------------

class TestLookupKernel:
    def _table(self, capacity, n, seed):
        rng = np.random.default_rng(seed)
        t = DeviceWindowTable(capacity, max_probes=8)
        ck = np.sort(rng.integers(-(2 ** 40), 2 ** 40, size=n))
        cs = rng.integers(-50, 50, size=n) * 7
        t.update(ck, cs, cs + 7, np.ones(n), np.ones(n), 0)
        return t, ck, cs

    @pytest.mark.parametrize("mode", ["ref", "interpret"])
    def test_dispatch_modes_match_numpy_probe(self, mode):
        t, ck, cs = self._table(64, 40, 0)
        want = t.lookup(ck, cs)  # numpy probe-window realization
        ops.use_kernels(mode)
        try:
            got = np.asarray(
                ops.table_lookup(ck, cs, t.key, t.start, t.occ), np.int64
            )
        finally:
            ops.use_kernels("auto")
        np.testing.assert_array_equal(
            np.where(got >= t.capacity, -1, got), want
        )

    def test_kernel_padding_and_blocking(self):
        """Cell count and capacity that are NOT multiples of the block sizes
        exercise the padding convention (padded table rows unoccupied)."""
        from repro.kernels import hash_table as ht
        from repro.kernels import ref as kref

        t, ck, cs = self._table(37, 23, 1)
        cells = ops._split_i64(ck) + ops._split_i64(cs)
        table = ops._split_i64(t.key) + ops._split_i64(t.start)
        occ = np.asarray(t.occ, np.int32)
        got = np.asarray(
            ht.table_lookup(cells, table, occ, block_cells=8, block_table=16,
                            interpret=True)
        )
        want = np.asarray(kref.table_lookup_ref(cells, table, occ))
        np.testing.assert_array_equal(got, want)

    def test_engine_exact_through_kernel_dispatch(self):
        spec = WindowSpec("tumbling", size=7, lateness=3)
        items = synthetic_keyed_items(CHUNK * 5, num_keys=9, disorder=5,
                                      seed=2)
        o_em, _, _ = semantics.keyed_windows(
            "tumbling", _triples(items), **spec.oracle_kwargs(CHUNK)
        )
        ops.use_kernels("interpret")
        try:
            eng = KeyedWindowEngine(
                spec, num_slots=NUM_SLOTS, backend="device_table", capacity=64
            )
            outs = [
                eng.process_chunk(items[i: i + CHUNK])
                for i in range(0, len(items), CHUNK)
            ]
        finally:
            ops.use_kernels("auto")
        assert _emissions(outs) == o_em


# ---------------------------------------------------------------------------
# backend bit-exactness vs the serial oracle (the acceptance criterion)
# ---------------------------------------------------------------------------

class TestTableBackendBitExact:
    def _run_executor(self, spec, items, schedule, degree=2, **table_kw):
        ad = KeyedWindowAdapter(
            spec, num_slots=NUM_SLOTS, impl="segment",
            backend="device_table", **table_kw,
        )
        ex = StreamExecutor(ad, degree=degree, chunk_size=CHUNK)
        chunks = [items[i: i + CHUNK] for i in range(0, len(items), CHUNK)]
        outs = ex.run(chunks, schedule=schedule)
        return ex, outs

    @pytest.mark.parametrize("kind", ["tumbling", "sliding"])
    @pytest.mark.parametrize("cfg", TABLE_CONFIGS,
                             ids=["ample", "spill", "evict", "spill+evict"])
    def test_grow_shrink_nondivisible_degrees_bit_exact(self, kind, cfg):
        spec = _spec_for(kind)
        items = synthetic_keyed_items(
            11 * CHUNK + 9, num_keys=9, disorder=6, seed=13
        )
        ex, outs = self._run_executor(spec, items, {2: 3, 5: 7, 8: 2}, **cfg)
        o_em, o_open, o_late = semantics.keyed_windows(
            kind, _triples(items), **spec.oracle_kwargs(CHUNK)
        )
        assert _emissions(outs) == o_em
        assert _state_rows(ex.state) == [tuple(t) for t in o_open]
        late_rows = [
            tuple(int(x) for x in row)
            for o in outs
            for row in zip(*(o["late"][k]
                             for k in ("key", "value", "ts", "start")))
        ]
        assert late_rows == o_late
        assert all(
            r.protocol == "S2-slotmap-handoff" for r in ex.metrics.resizes
        )

    def test_forced_spill_and_eviction_really_happen(self):
        """The stress configs must actually exercise the tier transitions —
        otherwise the bit-exact parametrization proves nothing."""
        spec = WindowSpec("tumbling", size=200, lateness=4)
        n = 25 * CHUNK
        i = np.arange(n, dtype=np.int64)
        # hot set of 24 standing keys (> capacity: forces probe-window spill)
        # plus one-shot cold keys that go idle (forces TTL eviction)
        keys = np.where(i % CHUNK == 0, 1000 + i, i % 24)
        items = keyed_stream(keys, i % 13, i)
        ex, outs = self._run_executor(
            spec, items, {3: 7}, capacity=16, max_probes=2, ttl=10
        )
        assert int(ex.state["t_spilled"]) > 0
        assert int(ex.state["t_evicted"]) > 0
        o_em, o_open, _ = semantics.keyed_windows(
            "tumbling", _triples(items), **spec.oracle_kwargs(CHUNK)
        )
        assert _emissions(outs) == o_em
        assert _state_rows(ex.state) == [tuple(t) for t in o_open]

    def test_session_backend_stays_host_side_and_exact(self):
        spec = WindowSpec("session", gap=5, lateness=3, late_policy="side")
        eng = KeyedWindowEngine(
            spec, num_slots=NUM_SLOTS, backend="device_table", capacity=64
        )
        assert eng.table is None  # sessions merge by overlap: host tier
        items = synthetic_keyed_items(CHUNK * 6, num_keys=7, disorder=4,
                                      seed=4)
        outs = [
            eng.process_chunk(items[i: i + CHUNK])
            for i in range(0, len(items), CHUNK)
        ]
        o_em, o_open, _ = semantics.keyed_windows(
            "session", _triples(items), **spec.oracle_kwargs(CHUNK)
        )
        assert _emissions(outs) == o_em
        assert _state_rows(eng.snapshot()) == [tuple(t) for t in o_open]

    @settings(max_examples=8, deadline=None)
    @given(
        st.sampled_from(["tumbling", "sliding"]),
        st.integers(0, 10_000),
        st.integers(0, 10),
        st.sampled_from([(2, 3), (3, 7), (6, 4)]),
        st.sampled_from([(8, 2, 0), (16, 4, 2), (12, 3, 5)]),
    )
    def test_property_forced_evictions_spill_resize_bit_exact(
        self, kind, seed, disorder, degrees, table_cfg
    ):
        """Property (ISSUE satellite): random streams on a deliberately
        undersized table (every config forces spill and TTL churn), with a
        mid-stream resize between NON-divisor worker counts, match the
        serial oracle on emissions, late records, and final canonical
        state."""
        spec = _spec_for(kind)
        capacity, max_probes, ttl = table_cfg
        items = synthetic_keyed_items(
            8 * CHUNK + 5, num_keys=11, disorder=disorder, seed=seed
        )
        d0, d1 = degrees
        o_em, o_open, o_late = semantics.keyed_windows(
            kind, _triples(items), **spec.oracle_kwargs(CHUNK)
        )
        ex, outs = self._run_executor(
            spec, items, {3: d1, 6: d0}, degree=d0,
            capacity=capacity, max_probes=max_probes, ttl=ttl,
        )
        assert _emissions(outs) == o_em
        assert _state_rows(ex.state) == [tuple(t) for t in o_open]
        late_rows = [
            tuple(int(x) for x in row)
            for o in outs
            for row in zip(*(o["late"][k]
                             for k in ("key", "value", "ts", "start")))
        ]
        assert late_rows == o_late


# ---------------------------------------------------------------------------
# canonical snapshot: checkpoint round-trip + replay + resize accounting
# ---------------------------------------------------------------------------

class TestSnapshotRestore:
    def test_midstream_snapshot_restore_replays_identically(self):
        spec = WindowSpec("tumbling", size=40, lateness=6)
        items = synthetic_keyed_items(10 * CHUNK, num_keys=9, disorder=5,
                                      seed=7)
        kw = dict(backend="device_table", capacity=16, max_probes=4, ttl=8)
        a = KeyedWindowEngine(spec, num_slots=NUM_SLOTS, **kw)
        for i in range(0, 5 * CHUNK, CHUNK):
            a.process_chunk(items[i: i + CHUNK])
        snap = a.snapshot()
        b = KeyedWindowEngine.restore(spec, snap, **kw)
        outs_a, outs_b = [], []
        for i in range(5 * CHUNK, len(items), CHUNK):
            outs_a.append(a.process_chunk(items[i: i + CHUNK]))
            outs_b.append(b.process_chunk(items[i: i + CHUNK]))
        assert _emissions(outs_a) == _emissions(outs_b)
        sa, sb = a.snapshot(), b.snapshot()
        for k in sa:
            np.testing.assert_array_equal(sa[k], sb[k], err_msg=k)

    def test_restore_is_snapshot_fixed_point(self):
        spec = WindowSpec("sliding", size=9, slide=4, lateness=3)
        kw = dict(backend="device_table", capacity=32, ttl=4)
        eng = KeyedWindowEngine(spec, num_slots=NUM_SLOTS, **kw)
        items = synthetic_keyed_items(4 * CHUNK, num_keys=8, disorder=4,
                                      seed=9)
        for i in range(0, len(items), CHUNK):
            eng.process_chunk(items[i: i + CHUNK])
        snap = eng.snapshot()
        again = KeyedWindowEngine.restore(spec, snap, **kw).snapshot()
        for k in snap:
            np.testing.assert_array_equal(snap[k], again[k], err_msg=k)

    def test_pr2_host_snapshot_restores_without_placement_columns(self):
        """Backward compat: a PR 2 pytree (no w_resident / w_touch / t_*)
        restores into either backend; the table backend starts the rows on
        the host tier and adopts them lazily."""
        spec = WindowSpec("tumbling", size=7, lateness=3)
        host = KeyedWindowEngine(spec, num_slots=NUM_SLOTS)
        items = synthetic_keyed_items(CHUNK * 3, num_keys=6, disorder=3,
                                      seed=5)
        outs = [host.process_chunk(items[i: i + CHUNK])
                for i in range(0, len(items), CHUNK)]
        del outs
        old = {
            k: v for k, v in host.snapshot().items()
            if not k.startswith(("w_resident", "w_touch", "t_"))
        }
        for backend in ("host", "device_table"):
            eng = KeyedWindowEngine.restore(
                spec, old, backend=backend, capacity=32
            )
            assert _state_rows(eng.snapshot()) == _state_rows(host.snapshot())

    def test_supervisor_checkpoint_replay_covers_device_table(self, tmp_path):
        """Failure -> rollback -> replay with the device-table backend under
        a spill+TTL stress config: bit-exact vs the oracle end to end."""
        from repro.runtime import BoundedSource

        spec = WindowSpec("tumbling", size=30, lateness=5, late_policy="side")
        NCH = 6
        items = synthetic_keyed_items(CHUNK * NCH, num_keys=7, disorder=5,
                                      seed=3)
        src = BoundedSource(items)

        def chunk_fn(i):
            src.seek(i * CHUNK)
            return src.take(CHUNK)

        ad = KeyedWindowAdapter(
            spec, num_slots=10, impl="segment", backend="device_table",
            capacity=8, max_probes=2, ttl=4,
        )
        ex = StreamExecutor(ad, degree=3, chunk_size=CHUNK)
        sup = Supervisor(
            ex, chunk_fn, num_chunks=NCH, ckpt_dir=str(tmp_path),
            ckpt_every=2, failure_plan=FailurePlan(fail_at=3, recover_after=2),
        )
        outs = sup.run()
        o_em, o_open, _ = semantics.keyed_windows(
            "tumbling", _triples(items), **spec.oracle_kwargs(CHUNK)
        )
        assert _emissions([outs[i] for i in range(NCH)]) == o_em
        assert _state_rows(ex.state) == [tuple(t) for t in o_open]
        kinds = [e.kind for e in sup.events]
        assert "failure" in kinds and "shrink" in kinds and "grow" in kinds

    def test_migrated_rows_empty_table_and_empty_moved_set(self):
        """Edge cases: no open rows, or a no-op move set, must both report
        zero handoff volume (and not trip on empty-array hashing)."""
        spec = WindowSpec("tumbling", size=8, lateness=2)
        fresh = KeyedWindowEngine(
            spec, num_slots=NUM_SLOTS, backend="device_table", capacity=16
        ).snapshot()
        assert len(fresh["w_key"]) == 0
        assert migrated_rows(fresh, np.arange(NUM_SLOTS)) == 0
        eng = KeyedWindowEngine(spec, num_slots=NUM_SLOTS,
                                backend="device_table", capacity=16)
        eng.process_chunk(synthetic_keyed_items(CHUNK, num_keys=6, seed=0))
        populated = eng.snapshot()
        assert len(populated["w_key"]) > 0
        assert migrated_rows(populated, np.zeros(0, np.int64)) == 0
        assert migrated_rows(populated, []) == 0

    def test_migrated_rows_counts_spill_tier_rows(self):
        """Rows resident in the spill tier ride a slot migration exactly
        like table-resident rows: migrated_rows counts by slot ownership,
        never by placement — moving every slot moves every open row."""
        spec = WindowSpec("tumbling", size=500, lateness=2)
        eng = KeyedWindowEngine(
            spec, num_slots=NUM_SLOTS, backend="device_table",
            capacity=4, max_probes=1,  # force probe-window spill
        )
        items = synthetic_keyed_items(4 * CHUNK, num_keys=40, disorder=2,
                                      seed=6)
        for i in range(0, len(items), CHUNK):
            eng.process_chunk(items[i: i + CHUNK])
        snap = eng.snapshot()
        assert eng.table.stats.spilled > 0
        resident = np.asarray(snap["w_resident"], np.int64)
        assert (resident == 0).any() and (resident == 1).any()  # both tiers
        assert migrated_rows(snap, np.arange(NUM_SLOTS)) == len(snap["w_key"])
        # a partial move counts exactly the rows of the moved slots,
        # regardless of tier
        from repro.keyed import hash_to_slot

        moved = np.arange(NUM_SLOTS // 2)
        keys = np.asarray(snap["w_key"], np.int64)
        slots = np.asarray(hash_to_slot(keys, NUM_SLOTS), np.int64)
        assert migrated_rows(snap, moved) == int(np.isin(slots, moved).sum())

    def test_validate_degree_bounds(self):
        """The slot-map adapter accepts every degree in [1, num_slots] at
        any chunk size, and rejects both out-of-range ends."""
        ad = KeyedWindowAdapter(
            WindowSpec("tumbling", size=4), num_slots=NUM_SLOTS
        )
        for n_w in (1, 2, NUM_SLOTS - 1, NUM_SLOTS):
            ad.validate_degree(CHUNK, n_w)       # chunk need not divide
            ad.validate_degree(CHUNK + 1, n_w)
        for bad in (0, -1, NUM_SLOTS + 1, 10 * NUM_SLOTS):
            with pytest.raises(ValueError, match="worker count"):
                ad.validate_degree(CHUNK, bad)

    def test_resize_accounting_reports_migrated_table_rows(self):
        spec = WindowSpec("tumbling", size=64, lateness=4)
        ad = KeyedWindowAdapter(
            spec, num_slots=NUM_SLOTS, backend="device_table", capacity=64
        )
        ex = StreamExecutor(ad, degree=2, chunk_size=CHUNK)
        items = synthetic_keyed_items(CHUNK * 3, num_keys=12, disorder=2,
                                      seed=1)
        for i in range(0, len(items), CHUNK):
            ex.process(items[i: i + CHUNK])
        state_before = dict(ex.state)
        rec = ex.set_degree(7)
        assert rec is not None and rec.protocol == "S2-slotmap-handoff"
        assert "table rows" in rec.reason
        # the detail's row count is exactly the moved-slot row population
        from repro.keyed import SlotMap

        slot_table = np.asarray(state_before["slot_table"], np.int32)
        _, moved = SlotMap(
            len(slot_table), int(state_before["n_workers"]), table=slot_table
        ).rebalance(7)
        n_rows = migrated_rows(state_before, moved)
        assert f"({n_rows} table rows)" in rec.reason
        assert n_rows > 0
