"""Tests for the fused batched shard plane (ISSUE 5).

Acceptance contract: the fused all-shard pass — route once, expand panes
once, dedup cells once, ONE batched table lookup + scatter dispatch, one
global watermark close — is **bit-identical** to the ``fused=False``
per-shard loop AND to :func:`repro.core.semantics.keyed_windows` across
mid-stream grow/shrink at non-divisor degrees, forced spill / TTL
eviction, and early-firing provisional panes, on both state backends.
Plus the satellites: the vectorized host-store merge is bit-exact, the
zero-row donor path allocates/ships nothing, and the executor's
double-buffered chunk pipeline changes no output.
"""

import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import semantics
from repro.keyed import (
    BatchedWindowTable,
    DeviceWindowTable,
    KeyedWindowAdapter,
    KeyedWindowEngine,
    WindowSpec,
    synthetic_keyed_items,
)
from repro.runtime import StreamExecutor

NUM_SLOTS = 20  # degrees 3, 6, 7 do not divide this
CHUNK = 16


def _triples(items):
    return [(int(r["key"]), int(r["value"]), int(r["ts"])) for r in items]


def _rows(d, cols=("key", "start", "end", "value", "count")):
    return [tuple(int(x) for x in row) for row in zip(*(d[k] for k in cols))]


def _emissions(outs, channel="emissions"):
    return [r for o in outs for r in _rows(o[channel])]


def _late(outs):
    return [
        r for o in outs for r in _rows(o["late"], ("key", "value", "ts",
                                                   "start"))
    ]


def _state_rows(state):
    return [
        tuple(int(x) for x in r)
        for r in zip(
            *(np.asarray(state[k]).tolist()
              for k in ("w_key", "w_start", "w_end", "w_value", "w_count"))
        )
    ]


def _spec_for(kind, early_every=0):
    if kind == "tumbling":
        return WindowSpec("tumbling", size=7, lateness=3, late_policy="side",
                          early_every=early_every)
    if kind == "sliding":
        return WindowSpec("sliding", size=9, slide=4, lateness=3,
                          late_policy="side", early_every=early_every)
    return WindowSpec("session", gap=5, lateness=3, late_policy="side",
                      early_every=early_every)


def _chunks(items):
    return [items[i: i + CHUNK] for i in range(0, len(items), CHUNK)]


def _assert_outputs_equal(outs_a, outs_b):
    assert len(outs_a) == len(outs_b)
    for a, b in zip(outs_a, outs_b):
        for ch in ("emissions", "late", "early"):
            assert set(a[ch]) == set(b[ch])
            for k in a[ch]:
                np.testing.assert_array_equal(
                    a[ch][k], b[ch][k], err_msg=f"{ch}/{k}"
                )


def _assert_states_equal(sa, sb):
    assert set(sa) == set(sb)
    for k in sa:
        np.testing.assert_array_equal(sa[k], sb[k], err_msg=k)


# ---------------------------------------------------------------------------
# fused plane == per-shard loop == serial oracle (the acceptance criterion)
# ---------------------------------------------------------------------------

class TestFusedBitExact:
    @settings(max_examples=6, deadline=None)
    @given(
        st.sampled_from(["tumbling", "sliding", "session"]),
        st.integers(0, 10_000),
        st.integers(0, 10),
        st.sampled_from([(2, 5), (3, 7), (6, 4)]),
    )
    def test_property_fused_equals_loop_and_oracle(
        self, kind, seed, disorder, degrees
    ):
        """Property: random keyed streams with bounded disorder, grow AND
        shrink at non-divisor degrees, early firing on, a device table tiny
        enough to force spill and TTL eviction — the fused pass agrees with
        the per-shard loop bit-for-bit on every output channel and every
        barrier-snapshot key, and both match the serial oracle."""
        spec = _spec_for(kind, early_every=3)
        items = synthetic_keyed_items(
            8 * CHUNK + 5, num_keys=7, disorder=disorder, seed=seed
        )
        d0, d1 = degrees
        o_em, o_open, o_late, o_early = semantics.keyed_windows(
            kind, _triples(items), **spec.oracle_kwargs(CHUNK)
        )
        for backend, kw in (
            ("host", {}),
            ("device_table", dict(capacity=16, max_probes=2, ttl=4)),
        ):
            outs, states = {}, {}
            for fused in (True, False):
                ad = KeyedWindowAdapter(
                    spec, num_slots=NUM_SLOTS, impl="segment",
                    backend=backend, fused=fused, **kw,
                )
                ex = StreamExecutor(ad, degree=d0, chunk_size=CHUNK)
                outs[fused] = ex.run(_chunks(items), schedule={3: d1, 6: d0})
                states[fused] = ex.state
            assert _emissions(outs[True]) == o_em
            assert _emissions(outs[True], "early") == o_early
            assert _late(outs[True]) == o_late
            assert _state_rows(states[True]) == [tuple(t) for t in o_open]
            _assert_outputs_equal(outs[True], outs[False])
            _assert_states_equal(states[True], states[False])

    def test_fused_shards_hold_only_owned_rows(self):
        """The fused pass preserves physical ownership: after batched
        updates, spills, and a resize, every row a shard holds hashes to a
        slot the slot map assigns it."""
        from repro.keyed import hash_to_slot

        spec = _spec_for("sliding")
        items = synthetic_keyed_items(6 * CHUNK, num_keys=17, disorder=4,
                                      seed=2)
        ad = KeyedWindowAdapter(
            spec, num_slots=NUM_SLOTS, backend="device_table",
            capacity=16, max_probes=2, fused=True,
        )
        ex = StreamExecutor(ad, degree=3, chunk_size=CHUNK)
        ex.run(_chunks(items), schedule={2: 7})
        union = []
        for w, eng in enumerate(ad.shards):
            snap = eng.snapshot()
            keys = np.asarray(snap["w_key"], np.int64)
            slots = hash_to_slot(keys, NUM_SLOTS).astype(np.int64)
            owners = np.asarray(ad._slot_map.table, np.int64)[slots]
            assert (owners == w).all(), f"shard {w} holds foreign rows"
            union.extend(_state_rows(snap))
        assert sorted(union) == _state_rows(ex.state)

    def test_batched_plane_rebuilds_across_resize(self):
        """grow/shrink re-stacks the batched view over the new shard set;
        the plane keeps matching the per-shard tables row for row."""
        spec = WindowSpec("tumbling", size=64, lateness=4)
        items = synthetic_keyed_items(CHUNK * 3, num_keys=12, disorder=2,
                                      seed=1)
        ad = KeyedWindowAdapter(
            spec, num_slots=NUM_SLOTS, backend="device_table", capacity=64,
        )
        ex = StreamExecutor(ad, degree=2, chunk_size=CHUNK)
        for c in _chunks(items):
            ex.process(c)
        sem_keys = ("w_key", "w_start", "w_end", "w_value", "w_count",
                    "wm", "wm_valid", "wm_ticks", "max_ts", "max_ts_valid",
                    "late_count")
        for n_new in (7, 3):
            before = ex.snapshot_barrier()
            ex.set_degree(n_new)
            assert ad._batched is not None
            assert ad._batched.n_shards == n_new
            # plane storage IS the shard tables' storage: both the shard
            # views and the plane's active-prefix view slice the same
            # over-allocated backing array
            for eng in ad.shards:
                assert eng.table.key.base is ad._batched._akey
                assert np.shares_memory(eng.table.key, ad._batched.key)
            after = ex.snapshot_barrier()
            # semantic state rides the migration unchanged (placement
            # counters legitimately move: re-insertion counts as inserts)
            for k in sem_keys:
                np.testing.assert_array_equal(after[k], before[k],
                                              err_msg=k)


# ---------------------------------------------------------------------------
# batched table + lookup kernel
# ---------------------------------------------------------------------------

class TestBatchedWindowTable:
    def test_plane_is_a_view_over_shard_tables(self):
        tables = [DeviceWindowTable(8, max_probes=4) for _ in range(2)]
        bt = BatchedWindowTable(tables)
        bt.update(
            np.array([1], np.int64), np.array([42], np.int64),
            np.array([0], np.int64), np.array([4], np.int64),
            np.array([5], np.int64), np.array([1], np.int64), touch_ts=3,
        )
        # the batched write landed in shard 1's (view) table only
        assert tables[0].occupancy == 0 and tables[1].occupancy == 1
        row = tables[1].rows()[0]
        assert row[0] == 42 and row[3] == 5 and row[5] == 3
        # and a per-shard mutation is visible to the plane
        tables[1].clear()
        assert not bt._focc.any()

    def test_batched_lookup_paths_agree(self):
        """numpy probe window, jnp reference, and the Pallas interpret
        kernel return the identical global row for hits and the miss
        sentinel for absent cells — negative keys/starts included."""
        from repro.kernels import ops

        tables = [DeviceWindowTable(8, max_probes=4) for _ in range(3)]
        bt = BatchedWindowTable(tables)
        owners = np.array([0, 0, 1, 2, 2, 2], np.int64)
        keys = np.array([-5, 3, 9, 7, 2, 11], np.int64)
        starts = np.array([0, 4, 4, 8, 0, -12], np.int64)
        spill = bt.update(owners, keys, starts, starts + 4,
                          np.ones(6, np.int64), np.ones(6, np.int64),
                          touch_ts=5)
        assert spill is None
        q_own = np.concatenate([owners, [1, 0]])
        q_key = np.concatenate([keys, [999, -5]])
        q_start = np.concatenate([starts, [0, 4]])  # two absent cells
        got = {}
        for mode in ("ref", "interpret"):
            ops.use_kernels(mode)
            try:
                got[mode] = np.asarray(
                    ops.batched_table_lookup(
                        q_own, q_key, q_start, bt.row_owner, bt._fkey,
                        bt._fstart, bt._focc,
                    ),
                    np.int64,
                )
            finally:
                ops.use_kernels("auto")
        np.testing.assert_array_equal(got["ref"], got["interpret"])
        assert (got["ref"][-2:] == bt.total_rows).all()
        probe = bt.lookup(q_own, q_key, q_start)
        np.testing.assert_array_equal(
            probe,
            np.where(got["ref"] >= bt.total_rows, np.int64(-1), got["ref"]),
        )
        # every hit resolves inside the owner's shard segment
        hits = probe[:-2]
        assert (hits // bt.capacity == owners).all()


# ---------------------------------------------------------------------------
# vectorized host-store merge (ISSUE satellite — regression)
# ---------------------------------------------------------------------------

class TestMergeIntoStore:
    def test_vectorized_merge_matches_scalar_reference(self):
        """The grouped np.unique/searchsorted merge must produce exactly
        the state the old per-row loop built: accumulate on (key, start)
        match, first-seen end wins, per-key lists start-sorted."""
        rng = np.random.default_rng(3)
        eng = KeyedWindowEngine(
            WindowSpec("tumbling", size=8), num_slots=NUM_SLOTS
        )
        ref = {}
        for _ in range(25):
            m = int(rng.integers(1, 12))
            keys = rng.integers(-4, 5, m)
            starts = rng.integers(0, 4, m) * 8
            vals = rng.integers(0, 10, m)
            cnts = rng.integers(1, 4, m)
            eng._merge_into_store(keys, starts, starts + 8, vals, cnts)
            for k, s, v, c in zip(keys.tolist(), starts.tolist(),
                                  vals.tolist(), cnts.tolist()):
                cell = ref.setdefault((k, s), [s + 8, 0, 0])
                cell[1] += v
                cell[2] += c
        got = sorted(
            (k, w.start, w.end, w.value, w.count)
            for sd in eng.store.slots for k, wins in sd.items() for w in wins
        )
        want = sorted(
            (k, s, e, v, c) for (k, s), (e, v, c) in ref.items()
        )
        assert got == want
        for sd in eng.store.slots:
            for wins in sd.values():
                assert [w.start for w in wins] == sorted(
                    w.start for w in wins
                )

    def test_forced_spill_eviction_engine_matches_oracle(self):
        """Under a pathological table (capacity 4, 1 probe, ttl 1) every
        chunk exercises the vectorized spill/evict merge — emissions and
        final state must stay bit-exact against the serial oracle."""
        spec = WindowSpec("sliding", size=9, slide=4, lateness=3,
                          late_policy="side")
        items = synthetic_keyed_items(7 * CHUNK, num_keys=11, disorder=4,
                                      seed=17)
        eng = KeyedWindowEngine(
            spec, num_slots=NUM_SLOTS, backend="device_table", capacity=4,
            max_probes=1, ttl=1,
        )
        outs = [eng.process_chunk(c) for c in _chunks(items)]
        assert eng.table.stats.spilled > 0 or eng.table.stats.evicted > 0
        o_em, o_open, o_late = semantics.keyed_windows(
            "sliding", _triples(items), **spec.oracle_kwargs(CHUNK)
        )
        assert _emissions(outs) == o_em
        snap = eng.snapshot()
        assert _state_rows(snap) == [tuple(t) for t in o_open]


# ---------------------------------------------------------------------------
# zero-row donors (ISSUE satellite — regression)
# ---------------------------------------------------------------------------

class TestZeroRowDonor:
    def test_live_resize_with_empty_plane_ships_nothing(self, monkeypatch):
        """A live resize whose moved slots hold no open windows must not
        build any per-recipient batch: recipients' ingest_rows is never
        called, and the ResizeInfo reports zero rows/bytes."""
        spec = WindowSpec("tumbling", size=8, lateness=0)
        ad = KeyedWindowAdapter(
            spec, num_slots=NUM_SLOTS, backend="device_table", capacity=32,
        )
        ad.attach(ad.init_state(), 2)
        calls = []
        monkeypatch.setattr(
            KeyedWindowEngine, "ingest_rows",
            lambda self, *a, **k: calls.append(a),
        )
        info = ad.resize_live(2, 5)
        assert calls == []
        assert info.handoff_items > 0  # ownership still moved
        assert info.handoff_rows == 0 and info.handoff_bytes == 0

    def test_no_handoff_record_on_bus_when_rows_zero(self):
        """migration_volume must not report a DMA-path handoff for a
        metadata-only resize (rows == 0)."""
        spec = WindowSpec("tumbling", size=1 << 30, lateness=0)
        ad = KeyedWindowAdapter(spec, num_slots=NUM_SLOTS)
        ex = StreamExecutor(ad, degree=2, chunk_size=CHUNK)
        rec = ex.set_degree(5)  # empty canonical state: nothing to ship
        assert rec.handoff_rows == 0
        vol = ex.metrics.migration_volume()
        assert vol["resizes"] == 1
        assert vol["handoffs"] == 0
        assert vol["rows"] == 0 and vol["bytes"] == 0
        # ...and a resize that DOES ship rows is counted
        items = synthetic_keyed_items(4 * CHUNK, num_keys=60, seed=4)
        for c in _chunks(items):
            ex.process(c)
        rec = ex.set_degree(3)
        assert rec.handoff_rows > 0
        vol = ex.metrics.migration_volume()
        assert vol["resizes"] == 2 and vol["handoffs"] == 1
        assert vol["bytes"] == vol["rows"] * 56

    def test_concat_sorted_empty_and_single_part_fast_paths(self):
        from repro.keyed.runtime import _concat_sorted

        keys = ("key", "start", "end", "value", "count")
        empty = {k: np.zeros(0, np.int64) for k in keys}
        out = _concat_sorted([empty, empty, empty], keys)
        assert all(len(out[k]) == 0 for k in keys)
        one = {k: np.array([1, 2], np.int64) for k in keys}
        out = _concat_sorted([empty, one, empty], keys)
        for k in keys:
            np.testing.assert_array_equal(out[k], one[k])


# ---------------------------------------------------------------------------
# double-buffered chunk pipeline
# ---------------------------------------------------------------------------

class TestChunkPipeline:
    def test_pipeline_outputs_bit_identical(self):
        """The pipeline overlaps prepare(k+1) with step(k); outputs, resize
        behavior, and the final barrier snapshot must be bit-identical to
        the unpipelined run (the prepare stage is pure by contract)."""
        spec = _spec_for("sliding", early_every=2)
        items = synthetic_keyed_items(9 * CHUNK, num_keys=9, disorder=5,
                                      seed=7)
        res = {}
        for pipe in (True, False):
            ad = KeyedWindowAdapter(
                spec, num_slots=NUM_SLOTS, backend="device_table",
                capacity=32, max_probes=4, ttl=6,
            )
            ex = StreamExecutor(ad, degree=3, chunk_size=CHUNK,
                                pipeline=pipe)
            res[pipe] = (ex.run(_chunks(items), schedule={2: 7, 5: 2}),
                         ex.state)
        _assert_outputs_equal(res[True][0], res[False][0])
        _assert_states_equal(res[True][1], res[False][1])

    def test_prepared_ingest_survives_resize(self):
        """prepare_chunk is state-independent: a prep computed BEFORE a
        resize must drive the post-resize step to the identical output
        (ownership resolves against the current slot table at step time)."""
        spec = _spec_for("tumbling")
        items = synthetic_keyed_items(4 * CHUNK, num_keys=8, disorder=3,
                                      seed=2)
        chunks = _chunks(items)
        outs = {}
        for stale in (True, False):
            ad = KeyedWindowAdapter(spec, num_slots=NUM_SLOTS)
            ex = StreamExecutor(ad, degree=2, chunk_size=CHUNK,
                                pipeline=False)
            ex.process(chunks[0])
            prep = ad.prepare_chunk(chunks[1]) if stale else None
            ex.set_degree(7)
            outs[stale] = ex.process(chunks[1], prepared=prep)
        for ch in ("emissions", "late", "early"):
            for k in outs[True][ch]:
                np.testing.assert_array_equal(
                    outs[True][ch][k], outs[False][ch][k]
                )

    def test_mid_run_barrier_under_pipeline(self):
        """A checkpoint barrier (state read) between pipelined chunks
        drains the in-flight prepare and serializes the canonical form;
        the continuation stays oracle-exact."""
        spec = _spec_for("tumbling", early_every=2)
        items = synthetic_keyed_items(6 * CHUNK, num_keys=8, disorder=4,
                                      seed=9)
        chunks = _chunks(items)
        ad = KeyedWindowAdapter(spec, num_slots=NUM_SLOTS)
        ex = StreamExecutor(ad, degree=3, chunk_size=CHUNK)
        outs = ex.run(chunks[:3])
        snap = ex.snapshot_barrier()
        assert ex._inflight is None
        assert int(snap["wm_ticks"]) == 3
        outs += ex.run(chunks[3:])
        o_em, o_open, _, o_early = semantics.keyed_windows(
            "tumbling", _triples(items), **spec.oracle_kwargs(CHUNK)
        )
        assert _emissions(outs) == o_em
        assert _emissions(outs, "early") == o_early
        assert _state_rows(ex.state) == [tuple(t) for t in o_open]

    def test_tail_chunk_under_pipeline(self):
        """A short tail chunk forces a degree fit mid-pipeline; outputs
        stay oracle-exact."""
        spec = _spec_for("tumbling")
        items = synthetic_keyed_items(3 * CHUNK + 5, num_keys=6,
                                      disorder=2, seed=11)
        ad = KeyedWindowAdapter(spec, num_slots=NUM_SLOTS)
        ex = StreamExecutor(ad, degree=4, chunk_size=CHUNK)
        outs = ex.run(_chunks(items))
        o_em, o_open, _ = semantics.keyed_windows(
            "tumbling", _triples(items), **spec.oracle_kwargs(CHUNK)
        )[:3]
        assert _emissions(outs) == o_em
        assert _state_rows(ex.state) == [tuple(t) for t in o_open]
