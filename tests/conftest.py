"""Pytest bootstrap: provide `hypothesis` from the bundled fallback when the
real package is not installed (the CI container ships JAX but not hypothesis),
and dump the flight-recorder black box on the first test failure (CI uploads
``results/blackbox/`` as the ``tier1-blackbox`` artifact).
"""

import os
import sys
import types

import pytest

try:  # real hypothesis wins whenever it is available
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    import _hypothesis_fallback as _hf

    _mod = types.ModuleType("hypothesis")
    _mod.given = _hf.given
    _mod.settings = _hf.settings
    _st = types.ModuleType("hypothesis.strategies")
    for _name in ("integers", "floats", "lists", "sampled_from", "booleans"):
        setattr(_st, _name, getattr(_hf, _name))
    _mod.strategies = _st
    sys.modules["hypothesis"] = _mod
    sys.modules["hypothesis.strategies"] = _st


_BLACKBOX_DUMPED = False


@pytest.hookimpl(hookwrapper=True)
def pytest_runtest_makereport(item, call):
    """On the first test failure, dump the process-global flight recorder:
    the newest spans/instants/metric snapshots any enabled Tracer fed before
    the assertion — the suite's black box, loadable in Perfetto."""
    outcome = yield
    rep = outcome.get_result()
    global _BLACKBOX_DUMPED
    if rep.when != "call" or not rep.failed or _BLACKBOX_DUMPED:
        return
    _BLACKBOX_DUMPED = True
    try:
        from repro.obs.trace import FLIGHT_RECORDER

        if len(FLIGHT_RECORDER) == 0:
            return
        out = os.path.join(
            os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
            "results", "blackbox",
        )
        os.makedirs(out, exist_ok=True)
        safe = item.nodeid.replace("/", "_").replace("::", "-")
        FLIGHT_RECORDER.dump(os.path.join(out, f"{safe}.json"))
    except Exception:
        pass  # the black box must never mask the real test failure
