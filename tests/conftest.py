"""Pytest bootstrap: provide `hypothesis` from the bundled fallback when the
real package is not installed (the CI container ships JAX but not hypothesis).
"""

import os
import sys
import types

try:  # real hypothesis wins whenever it is available
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    import _hypothesis_fallback as _hf

    _mod = types.ModuleType("hypothesis")
    _mod.given = _hf.given
    _mod.settings = _hf.settings
    _st = types.ModuleType("hypothesis.strategies")
    for _name in ("integers", "floats", "lists", "sampled_from"):
        setattr(_st, _name, getattr(_hf, _name))
    _mod.strategies = _st
    sys.modules["hypothesis"] = _mod
    sys.modules["hypothesis.strategies"] = _st
