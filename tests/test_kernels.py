"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.moe_dispatch import moe_gather
from repro.kernels.ssd_scan import ssd_scan


def tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else dict(
        atol=3e-5, rtol=3e-5
    )


class TestFlashAttention:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize(
        "B,Hq,Hkv,Sq,Skv,hd",
        [
            (1, 4, 4, 256, 256, 64),
            (2, 4, 2, 256, 512, 64),
            (1, 4, 1, 128, 384, 128),
            (1, 8, 8, 512, 512, 64),
        ],
    )
    def test_causal(self, B, Hq, Hkv, Sq, Skv, hd, dtype):
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (B, Hq, Sq, hd), dtype)
        k = jax.random.normal(ks[1], (B, Hkv, Skv, hd), dtype)
        v = jax.random.normal(ks[2], (B, Hkv, Skv, hd), dtype)
        out = flash_attention(q, k, v, causal=True)
        want = ref.flash_attention_ref(
            q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32),
            causal=True,
        )
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(want), **tol(dtype)
        )

    @pytest.mark.parametrize("window", [64, 128])
    def test_sliding_window(self, window):
        ks = jax.random.split(jax.random.PRNGKey(1), 3)
        q = jax.random.normal(ks[0], (1, 2, 256, 64), jnp.float32)
        k = jax.random.normal(ks[1], (1, 2, 256, 64), jnp.float32)
        v = jax.random.normal(ks[2], (1, 2, 256, 64), jnp.float32)
        out = flash_attention(q, k, v, causal=True, window=window)
        want = ref.flash_attention_ref(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=3e-5)

    def test_softcap(self):
        ks = jax.random.split(jax.random.PRNGKey(2), 3)
        q = jax.random.normal(ks[0], (1, 2, 128, 64), jnp.float32)
        k = jax.random.normal(ks[1], (1, 2, 128, 64), jnp.float32)
        v = jax.random.normal(ks[2], (1, 2, 128, 64), jnp.float32)
        out = flash_attention(q, k, v, causal=True, softcap=30.0)
        want = ref.flash_attention_ref(q, k, v, causal=True, softcap=30.0)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=3e-5)

    def test_matches_model_attention(self):
        """Kernel == the model's chunked attention (different blocking)."""
        from repro.models.attention import attend_chunked

        ks = jax.random.split(jax.random.PRNGKey(3), 3)
        q = jax.random.normal(ks[0], (2, 4, 256, 64), jnp.float32)
        k = jax.random.normal(ks[1], (2, 2, 256, 64), jnp.float32)
        v = jax.random.normal(ks[2], (2, 2, 256, 64), jnp.float32)
        out = flash_attention(q, k, v, causal=True)
        # model layout is [B, S, H, hd]
        out2 = attend_chunked(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), mode="causal", block_q=128, block_k=128,
        ).transpose(0, 2, 1, 3)
        np.testing.assert_allclose(np.asarray(out), np.asarray(out2), atol=3e-5)


class TestDecodeAttention:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize(
        "B,Hq,Hkv,S,hd,valid", [(2, 4, 4, 512, 64, 300), (1, 8, 2, 1024, 128, 1024),
                                (2, 4, 1, 512, 64, 17)],
    )
    def test_vs_ref(self, B, Hq, Hkv, S, hd, valid, dtype):
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (B, Hq, hd), dtype)
        ck = jax.random.normal(ks[1], (B, Hkv, S, hd), dtype)
        cv = jax.random.normal(ks[2], (B, Hkv, S, hd), dtype)
        out = decode_attention(q, ck, cv, jnp.int32(valid))
        want = ref.decode_attention_ref(
            q.astype(jnp.float32), ck.astype(jnp.float32), cv.astype(jnp.float32),
            valid,
        )
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(want), **tol(dtype)
        )

    def test_window(self):
        ks = jax.random.split(jax.random.PRNGKey(1), 3)
        q = jax.random.normal(ks[0], (1, 2, 64), jnp.float32)
        ck = jax.random.normal(ks[1], (1, 2, 512, 64), jnp.float32)
        cv = jax.random.normal(ks[2], (1, 2, 512, 64), jnp.float32)
        out = decode_attention(q, ck, cv, jnp.int32(400), window=128)
        want = ref.decode_attention_ref(q, ck, cv, 400, window=128)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=3e-5)


class TestSSDScan:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize(
        "B,H,S,P,N,chunk", [(2, 2, 256, 64, 32, 64), (1, 4, 128, 32, 128, 128),
                            (1, 2, 512, 64, 64, 128)],
    )
    def test_vs_sequential(self, B, H, S, P, N, chunk, dtype):
        ks = jax.random.split(jax.random.PRNGKey(0), 5)
        x = (jax.random.normal(ks[0], (B, H, S, P)) * 0.5).astype(dtype)
        dt = jax.nn.softplus(jax.random.normal(ks[1], (B, H, S)))
        A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
        Bm = (jax.random.normal(ks[3], (B, H, S, N)) * 0.3).astype(dtype)
        Cm = (jax.random.normal(ks[4], (B, H, S, N)) * 0.3).astype(dtype)
        y, h = ssd_scan(x, dt, A, Bm, Cm, chunk=chunk)
        y_ref, h_ref = ref.ssd_scan_ref(x, dt, A, Bm, Cm)
        t = dict(atol=5e-2, rtol=5e-2) if dtype == jnp.bfloat16 else dict(
            atol=2e-4, rtol=2e-4
        )
        np.testing.assert_allclose(np.asarray(y, np.float32), np.asarray(y_ref, np.float32), **t)
        np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref), **t)

    def test_matches_model_ssd(self):
        """Kernel == the model's pure-jnp chunked SSD (mamba2.ssd_chunked)."""
        from repro.models.mamba2 import ssd_chunked

        ks = jax.random.split(jax.random.PRNGKey(7), 5)
        B, H, S, P, N = 2, 4, 256, 32, 64
        x = jax.random.normal(ks[0], (B, H, S, P)) * 0.5
        dt = jax.nn.softplus(jax.random.normal(ks[1], (B, H, S)))
        A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
        Bm = jax.random.normal(ks[3], (B, H, S, N)) * 0.3
        Cm = jax.random.normal(ks[4], (B, H, S, N)) * 0.3
        y, h = ssd_scan(x, dt, A, Bm, Cm, chunk=64)
        # model layout: [B, S, H, P] / [B, S, G, N]
        y2, h2 = ssd_chunked(
            x.transpose(0, 2, 1, 3), dt.transpose(0, 2, 1), A,
            Bm.transpose(0, 2, 1, 3), Cm.transpose(0, 2, 1, 3), chunk=64,
        )
        np.testing.assert_allclose(
            np.asarray(y), np.asarray(y2.transpose(0, 2, 1, 3)), atol=2e-4, rtol=2e-4
        )
        np.testing.assert_allclose(np.asarray(h), np.asarray(h2), atol=2e-4, rtol=2e-4)


class TestMoEGather:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("T,d,R", [(64, 128, 96), (128, 256, 128)])
    def test_vs_ref(self, T, d, R, dtype):
        ks = jax.random.split(jax.random.PRNGKey(0), 2)
        x = jax.random.normal(ks[0], (T, d), dtype)
        # include dummy rows (== T)
        row_token = jax.random.randint(ks[1], (R,), 0, T + 1).astype(jnp.int32)
        out = moe_gather(x, row_token)
        want = ref.moe_gather_ref(x, row_token)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(want))

    def test_combine_roundtrip(self):
        """gather -> identity expert -> combine == weighted one-hot matmul."""
        T, d, R = 32, 64, 48
        ks = jax.random.split(jax.random.PRNGKey(1), 3)
        x = jax.random.normal(ks[0], (T, d), jnp.float32)
        row_token = jax.random.randint(ks[1], (R,), 0, T + 1).astype(jnp.int32)
        w = jax.random.uniform(ks[2], (R,))
        buf = moe_gather(x, row_token)
        y = ref.moe_combine_ref(buf, row_token, w, T)
        onehot = (row_token[:, None] == jnp.arange(T)[None, :]).astype(jnp.float32)
        want = jnp.einsum("rt,r,rd->td", onehot, w, buf)
        np.testing.assert_allclose(np.asarray(y), np.asarray(want), atol=1e-5)
