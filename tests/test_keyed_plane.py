"""Tests for the sharded keyed state plane (`repro.keyed.runtime`).

Acceptance contract (ISSUE 4): the live per-worker engine shards — items
routed by ``hash_to_slot``, per-shard emissions merged deterministically,
resizes done by row-level slot migration between shards — are **bit-exact**
against :func:`repro.core.semantics.keyed_windows` across mid-stream
grow/shrink at non-divisor worker counts AND supervisor checkpoint-replay,
on both state backends.  Plus: the snapshot barrier equals the global
engine's canonical snapshot, migration accounting (slots/rows/bytes) is
exact, worker-item tallies fold (not truncate) on shrink, and early-firing
triggers match the oracle.
"""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import semantics
from repro.keyed import (
    KeyedWindowAdapter,
    KeyedWindowEngine,
    SlotMap,
    WindowSpec,
    fold_worker_items,
    hash_to_slot,
    migrated_rows,
    synthetic_keyed_items,
)
from repro.runtime import (
    Autoscaler,
    FailurePlan,
    QueueDepthPolicy,
    StreamExecutor,
    Supervisor,
)

NUM_SLOTS = 20  # degrees 3, 6, 7 do not divide this
CHUNK = 16


def _triples(items):
    return [(int(r["key"]), int(r["value"]), int(r["ts"])) for r in items]


def _rows(d, cols=("key", "start", "end", "value", "count")):
    return [tuple(int(x) for x in row) for row in zip(*(d[k] for k in cols))]


def _emissions(outs, channel="emissions"):
    return [r for o in outs for r in _rows(o[channel])]


def _late(outs):
    return [
        r for o in outs for r in _rows(o["late"], ("key", "value", "ts",
                                                   "start"))
    ]


def _state_rows(state):
    return [
        tuple(int(x) for x in r)
        for r in zip(
            *(np.asarray(state[k]).tolist()
              for k in ("w_key", "w_start", "w_end", "w_value", "w_count"))
        )
    ]


def _spec_for(kind, early_every=0):
    if kind == "tumbling":
        return WindowSpec("tumbling", size=7, lateness=3, late_policy="side",
                          early_every=early_every)
    if kind == "sliding":
        return WindowSpec("sliding", size=9, slide=4, lateness=3,
                          late_policy="side", early_every=early_every)
    return WindowSpec("session", gap=5, lateness=3, late_policy="side",
                      early_every=early_every)


def _executor(spec, *, degree=2, backend="host", live=True, **table_kw):
    ad = KeyedWindowAdapter(
        spec, num_slots=NUM_SLOTS, impl="segment", backend=backend,
        live=live, **table_kw,
    )
    return ad, StreamExecutor(ad, degree=degree, chunk_size=CHUNK)


def _chunks(items):
    return [items[i: i + CHUNK] for i in range(0, len(items), CHUNK)]


# ---------------------------------------------------------------------------
# the sharded plane vs the serial oracle (the acceptance criterion)
# ---------------------------------------------------------------------------

class TestShardedPlaneBitExact:
    @pytest.mark.parametrize("kind", ["tumbling", "sliding", "session"])
    @pytest.mark.parametrize(
        "backend,table_kw",
        [("host", {}), ("device_table", dict(capacity=32, max_probes=4,
                                             ttl=6))],
        ids=["host", "device_table"],
    )
    def test_grow_shrink_nondivisor_degrees_bit_exact(
        self, kind, backend, table_kw
    ):
        """Live shards with mid-stream grow (2->3->7) and shrink (7->2) at
        degrees that do NOT divide num_slots=20, bit-exact vs the serial
        fold — emissions, early firings, late records, final state."""
        spec = _spec_for(kind, early_every=2)
        items = synthetic_keyed_items(
            11 * CHUNK + 9, num_keys=9, disorder=6, seed=13
        )
        ad, ex = _executor(spec, backend=backend, **table_kw)
        outs = ex.run(_chunks(items), schedule={2: 3, 5: 7, 8: 2})
        o_em, o_open, o_late, o_early = semantics.keyed_windows(
            kind, _triples(items), **spec.oracle_kwargs(CHUNK)
        )
        assert ad.shards is not None and len(ad.shards) == 2  # live, post-shrink
        assert _emissions(outs) == o_em
        assert _emissions(outs, "early") == o_early
        assert _late(outs) == o_late
        assert _state_rows(ex.state) == [tuple(t) for t in o_open]
        assert int(ex.state["late_count"]) == len(o_late)
        assert all(
            r.protocol == "S2-slotmap-handoff" for r in ex.metrics.resizes
        )
        # the migration plane actually shipped rows on the metrics bus
        vol = ex.metrics.migration_volume()
        assert vol["slots"] > 0 and vol["bytes"] == vol["rows"] * 56

    @settings(max_examples=6, deadline=None)
    @given(
        st.sampled_from(["tumbling", "sliding", "session"]),
        st.integers(0, 10_000),
        st.integers(0, 10),
        st.sampled_from([(2, 5), (3, 7), (6, 4)]),
    )
    def test_property_random_streams_and_resizes(
        self, kind, seed, disorder, degrees
    ):
        """Property: random keyed streams with bounded disorder and random
        grow/shrink between non-divisor degrees — the sharded plane agrees
        with the oracle on every output channel, both backends."""
        spec = _spec_for(kind, early_every=3)
        items = synthetic_keyed_items(
            8 * CHUNK + 5, num_keys=7, disorder=disorder, seed=seed
        )
        d0, d1 = degrees
        o_em, o_open, o_late, o_early = semantics.keyed_windows(
            kind, _triples(items), **spec.oracle_kwargs(CHUNK)
        )
        for backend, kw in (
            ("host", {}),
            ("device_table", dict(capacity=16, max_probes=4, ttl=4)),
        ):
            ad, ex = _executor(spec, degree=d0, backend=backend, **kw)
            outs = ex.run(_chunks(items), schedule={3: d1, 6: d0})
            assert _emissions(outs) == o_em
            assert _emissions(outs, "early") == o_early
            assert _late(outs) == o_late
            assert _state_rows(ex.state) == [tuple(t) for t in o_open]

    def test_shards_hold_only_owned_rows(self):
        """Ownership is physical: every row a shard holds hashes to a slot
        the slot map assigns it, and the shard union is the global state."""
        spec = _spec_for("sliding")
        items = synthetic_keyed_items(6 * CHUNK, num_keys=17, disorder=4,
                                      seed=2)
        ad, ex = _executor(spec, degree=3, backend="device_table",
                           capacity=16, max_probes=2)
        ex.run(_chunks(items), schedule={2: 7})
        assert len(ad.shards) == 7
        union = []
        for w, eng in enumerate(ad.shards):
            snap = eng.snapshot()
            keys = np.asarray(snap["w_key"], np.int64)
            slots = hash_to_slot(keys, NUM_SLOTS).astype(np.int64)
            owners = np.asarray(ad._slot_map.table, np.int64)[slots]
            assert (owners == w).all(), f"shard {w} holds foreign rows"
            union.extend(_state_rows(snap))
        assert sorted(union) == _state_rows(ex.state)

    def test_barrier_snapshot_equals_global_engine(self):
        """The merged barrier snapshot is THE canonical snapshot: a single
        global engine fed the same stream serializes identically (host
        backend: bit-identical on every key; device backend: identical on
        all semantic columns — residency is placement, not meaning)."""
        spec = _spec_for("tumbling", early_every=2)
        items = synthetic_keyed_items(7 * CHUNK, num_keys=9, disorder=5,
                                      seed=11)
        eng = KeyedWindowEngine(spec, num_slots=NUM_SLOTS)
        for c in _chunks(items):
            eng.process_chunk(c)
        want = eng.snapshot()
        ad, ex = _executor(spec, degree=6)
        ex.run(_chunks(items))
        got = ex.snapshot_barrier()
        # ownership table differs by design (degree 6 vs 1); rows must not
        want = dict(want, slot_table=got["slot_table"],
                    n_workers=got["n_workers"],
                    worker_items=got["worker_items"])
        assert set(got) == set(want)
        for k in want:
            np.testing.assert_array_equal(got[k], want[k], err_msg=k)
        # the work tallies sum to the same total the global engine counted
        assert int(np.sum(got["worker_items"])) == int(np.sum(
            eng.worker_items))

    def test_state_write_detaches_and_reattach_replays(self):
        """Writing executor.state (what checkpoint restore does) drops the
        live shards; the next chunk re-attaches from the canonical form and
        the continuation is bit-exact."""
        spec = _spec_for("tumbling")
        items = synthetic_keyed_items(8 * CHUNK, num_keys=8, disorder=4,
                                      seed=5)
        chunks = _chunks(items)
        ad, ex = _executor(spec, degree=3)
        outs = [ex.process(c) for c in chunks[:4]]
        mid = ex.state
        assert ad.shards is not None
        ex.state = mid  # external state write
        assert ad.shards is None
        outs += [ex.process(c) for c in chunks[4:]]
        o_em, o_open, _ = semantics.keyed_windows(
            "tumbling", _triples(items), **spec.oracle_kwargs(CHUNK)
        )
        assert _emissions(outs) == o_em
        assert _state_rows(ex.state) == [tuple(t) for t in o_open]


# ---------------------------------------------------------------------------
# row-level migration accounting
# ---------------------------------------------------------------------------

class TestRowMigration:
    def test_live_resize_ships_exactly_the_moved_rows(self):
        spec = WindowSpec("tumbling", size=64, lateness=4)
        items = synthetic_keyed_items(CHUNK * 3, num_keys=12, disorder=2,
                                      seed=1)
        for backend, kw in (
            ("host", {}),
            ("device_table", dict(capacity=64)),
        ):
            ad, ex = _executor(spec, backend=backend, **kw)
            for c in _chunks(items):
                ex.process(c)
            before = ex.snapshot_barrier()
            slot_table = np.asarray(before["slot_table"], np.int32)
            _, moved = SlotMap(
                len(slot_table), int(before["n_workers"]), table=slot_table
            ).rebalance(7)
            want_rows = migrated_rows(before, moved)
            rec = ex.set_degree(7)
            assert rec.protocol == "S2-slotmap-handoff"
            assert rec.handoff_items == len(moved)
            assert rec.handoff_rows == want_rows > 0
            assert rec.handoff_bytes == want_rows * 56
            assert f"({want_rows} table rows)" in rec.reason
            # migration moved rows without corrupting them
            after = ex.snapshot_barrier()
            assert _state_rows(after) == _state_rows(before)

    def test_autoscaler_decision_carries_migration_volume(self):
        spec = WindowSpec("tumbling", size=64, lateness=4)
        items = synthetic_keyed_items(CHUNK * 4, num_keys=12, disorder=2,
                                      seed=3)
        ad, ex = _executor(spec, degree=2)
        for c in _chunks(items):
            ex.process(c)

        class _Q:
            depth, high_watermark, low_watermark = 99, 8, 1

        sc = Autoscaler(QueueDepthPolicy(), [2, 3], cooldown_chunks=0)
        d = sc.maybe_scale(ex, queue=_Q())
        assert d is not None and d.applied
        assert d.handoff_slots > 0
        assert d.handoff_rows > 0
        assert d.handoff_bytes == d.handoff_rows * 56

    def test_supervisor_checkpoint_replay_over_live_shards(self, tmp_path):
        """Failure -> rollback to a barrier checkpoint -> replay over
        re-attached shards: bit-exact vs the oracle on both backends, with
        early firing on."""
        for backend, kw in (
            ("host", {}),
            ("device_table", dict(capacity=8, max_probes=2, ttl=4)),
        ):
            from repro.runtime import BoundedSource

            spec = WindowSpec("tumbling", size=30, lateness=5,
                              late_policy="side", early_every=2)
            NCH = 6
            items = synthetic_keyed_items(CHUNK * NCH, num_keys=7,
                                          disorder=5, seed=3)
            src = BoundedSource(items)

            def chunk_fn(i):
                src.seek(i * CHUNK)
                return src.take(CHUNK)

            ad = KeyedWindowAdapter(
                spec, num_slots=10, impl="segment", backend=backend, **kw
            )
            ex = StreamExecutor(ad, degree=3, chunk_size=CHUNK)
            sup = Supervisor(
                ex, chunk_fn, num_chunks=NCH,
                ckpt_dir=str(tmp_path / backend), ckpt_every=2,
                failure_plan=FailurePlan(fail_at=3, recover_after=2),
            )
            outs = sup.run()
            o_em, o_open, o_late, o_early = semantics.keyed_windows(
                "tumbling", _triples(items), **spec.oracle_kwargs(CHUNK)
            )
            ordered = [outs[i] for i in range(NCH)]
            assert _emissions(ordered) == o_em
            assert _emissions(ordered, "early") == o_early
            assert _late(ordered) == o_late
            assert _state_rows(ex.state) == [tuple(t) for t in o_open]
            kinds = [e.kind for e in sup.events]
            assert "failure" in kinds and "shrink" in kinds and "grow" in kinds
            assert ad.shards is not None  # the replay ran live


# ---------------------------------------------------------------------------
# worker-item tallies fold on shrink (ISSUE satellite — regression)
# ---------------------------------------------------------------------------

class TestWorkerItemsFold:
    def test_fold_preserves_sum_and_survivor_tallies(self):
        sm = SlotMap(NUM_SLOTS, 5)
        sm2, _ = sm.rebalance(2)
        old = np.array([10, 20, 30, 40, 50], np.int64)
        folded = fold_worker_items(old, sm.table, sm2.table, 2)
        assert folded.sum() == old.sum()  # nothing truncated
        assert (folded[:2] >= old[:2]).all()  # survivors only gain

    def test_fold_is_proportional_and_deterministic(self):
        # departing worker 2's four slots split 3 -> w0, 1 -> w1; its tally
        # follows in proportion (survivors keep their own tallies)
        old_table = np.array([0, 1, 2, 2, 2, 2], np.int64)
        new_table = np.array([0, 1, 0, 0, 0, 1], np.int64)
        folded = fold_worker_items(
            np.array([5, 9, 100], np.int64), old_table, new_table, 2
        )
        assert folded.tolist() == [5 + 75, 9 + 25]
        again = fold_worker_items(
            np.array([5, 9, 100], np.int64), old_table, new_table, 2
        )
        assert folded.tolist() == again.tolist()

    def test_fold_largest_remainder_conserves_odd_tallies(self):
        old_table = np.array([0, 1, 1, 1], np.int64)
        new_table = np.array([0, 0, 0, 0], np.int64)
        folded = fold_worker_items(
            np.array([0, 7], np.int64), old_table, new_table, 1
        )
        assert folded.tolist() == [7]

    @pytest.mark.parametrize("live", [True, False])
    def test_attach_at_different_degree_folds_tallies(self, live):
        """Regression (review finding): hydrating a snapshot written at one
        degree into an executor at another used to zero worker_items —
        attach must conserve the work metric like a resize does."""
        spec = WindowSpec("tumbling", size=7, lateness=3)
        items = synthetic_keyed_items(4 * CHUNK, num_keys=9, disorder=3,
                                      seed=6)
        _, ex4 = _executor(spec, degree=4)
        for c in _chunks(items):
            ex4.process(c)
        snap = ex4.state
        total = int(np.sum(np.asarray(snap["worker_items"], np.int64)))
        assert total > 0
        ad, ex2 = _executor(spec, degree=2, live=live)
        ex2.state = snap  # degree-4 snapshot into a degree-2 executor
        out = ex2.process(items[:CHUNK])  # triggers alignment + one chunk
        del out
        after = np.asarray(ex2.state["worker_items"], np.int64)
        assert len(after) == 2
        assert int(after.sum()) >= total  # folded tallies + the new chunk's

    @pytest.mark.parametrize("live", [True, False])
    def test_shrink_resize_folds_not_truncates(self, live):
        """Regression: a 7->2 shrink used to drop workers 2..6's tallies
        from the snapshot (metrics undercounted the §4.2 work
        distribution).  Both resize paths must conserve the total."""
        spec = WindowSpec("tumbling", size=7, lateness=3)
        items = synthetic_keyed_items(6 * CHUNK, num_keys=11, disorder=3,
                                      seed=9)
        ad, ex = _executor(spec, degree=7, live=live)
        for c in _chunks(items):
            ex.process(c)
        before = np.asarray(ex.state["worker_items"], np.int64)
        assert (before[2:] > 0).any()  # the departing workers did real work
        ex.set_degree(2)
        after = np.asarray(ex.state["worker_items"], np.int64)
        assert len(after) == 2
        assert after.sum() == before.sum()


# ---------------------------------------------------------------------------
# early-firing triggers (ISSUE satellite)
# ---------------------------------------------------------------------------

class TestEarlyFiring:
    @pytest.mark.parametrize("kind", ["tumbling", "sliding", "session"])
    def test_engine_matches_oracle(self, kind):
        spec = _spec_for(kind, early_every=2)
        items = synthetic_keyed_items(6 * CHUNK + 3, num_keys=8, disorder=4,
                                      seed=21)
        o_em, o_open, _, o_early = semantics.keyed_windows(
            kind, _triples(items), **spec.oracle_kwargs(CHUNK)
        )
        eng = KeyedWindowEngine(spec, num_slots=NUM_SLOTS)
        outs = [eng.process_chunk(c) for c in _chunks(items)]
        assert _emissions(outs) == o_em
        assert _emissions(outs, "early") == o_early
        assert len(o_early) > 0  # the trigger actually fired

    def test_early_firing_is_provisional(self):
        """Provisional panes never close windows: final emissions equal an
        early_every=0 run's, and early rows carry the running partials."""
        base = WindowSpec("tumbling", size=20, lateness=2)
        early = WindowSpec("tumbling", size=20, lateness=2, early_every=1)
        items = synthetic_keyed_items(4 * CHUNK, num_keys=5, disorder=2,
                                      seed=8)
        e0 = KeyedWindowEngine(base, num_slots=NUM_SLOTS)
        e1 = KeyedWindowEngine(early, num_slots=NUM_SLOTS)
        o0 = [e0.process_chunk(c) for c in _chunks(items)]
        o1 = [e1.process_chunk(c) for c in _chunks(items)]
        assert _emissions(o0) == _emissions(o1)
        assert all(len(o["early"]["key"]) == 0 for o in o0)
        assert any(len(o["early"]["key"]) > 0 for o in o1)

    def test_ticks_survive_snapshot_restore(self):
        spec = WindowSpec("tumbling", size=30, lateness=2, early_every=3)
        items = synthetic_keyed_items(7 * CHUNK, num_keys=6, disorder=2,
                                      seed=4)
        chunks = _chunks(items)
        a = KeyedWindowEngine(spec, num_slots=NUM_SLOTS)
        for c in chunks[:4]:
            a.process_chunk(c)
        b = KeyedWindowEngine.restore(spec, a.snapshot())
        assert b.wm_ticks == a.wm_ticks == 4
        for c in chunks[4:]:
            oa, ob = a.process_chunk(c), b.process_chunk(c)
            assert _rows(oa["early"]) == _rows(ob["early"])

    def test_spec_validation(self):
        with pytest.raises(ValueError):
            WindowSpec("tumbling", size=4, early_every=-1)
        with pytest.raises(ValueError):
            semantics.keyed_windows("tumbling", [], size=4, early_every=-2)
