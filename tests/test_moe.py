"""MoE layer tests: capacity dispatch invariants (hypothesis) + oracle
equivalence on a single device (the SPMD a2a path is covered by
tests/test_spmd.py)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models import moe as M
from repro.models.config import MoEConfig


class TestDispatchInvariants:
    @given(
        st.integers(min_value=1, max_value=6).map(lambda k: 2**k),  # tokens
        st.sampled_from([2, 4, 8]),                                  # experts
        st.sampled_from([1, 2]),                                     # top-k
        st.integers(min_value=0, max_value=2**31 - 1),
    )
    @settings(max_examples=30, deadline=None)
    def test_flat_dispatch_props(self, T, E, k, seed):
        rng = np.random.default_rng(seed)
        ids = jnp.asarray(rng.integers(0, E, size=(T * k,)), jnp.int32)
        w = jnp.asarray(rng.random(T * k), jnp.float32)
        cap = T * k  # generous: nothing dropped even if one expert takes all
        buf_token, buf_w = M._flat_dispatch(ids, w, E, cap, k=k)
        bt = np.asarray(buf_token)
        # every row is a valid token id or the dummy T
        assert ((bt >= 0) & (bt <= T)).all()
        # each (token, expert) assignment appears exactly once
        pairs = [(int(t), slot // cap) for slot, t in enumerate(bt) if t < T]
        want = [(i // k, int(e)) for i, e in enumerate(np.asarray(ids))]
        assert sorted(pairs) == sorted(want)
        # weights land with their rows
        total_w = float(np.asarray(buf_w).sum())
        assert total_w == pytest.approx(float(w.sum()), rel=1e-5)

    @given(st.integers(min_value=0, max_value=2**31 - 1))
    @settings(max_examples=15, deadline=None)
    def test_capacity_drops_bounded(self, seed):
        rng = np.random.default_rng(seed)
        T, E, k, cap = 64, 4, 2, 8
        ids = jnp.asarray(rng.integers(0, E, size=(T * k,)), jnp.int32)
        w = jnp.ones((T * k,), jnp.float32)
        buf_token, buf_w = M._flat_dispatch(ids, w, E, cap, k=k)
        kept = int((np.asarray(buf_token) < T).sum())
        assert kept <= E * cap
        # per-expert occupancy never exceeds capacity
        bt = np.asarray(buf_token).reshape(E, cap)
        assert ((bt <= T).sum(axis=1) <= cap).all()


class TestMoELayer:
    def test_matches_dense_oracle_no_drops(self):
        cfg = MoEConfig(num_experts=8, top_k=2, num_shared=1, d_ff_expert=64,
                        capacity_factor=8.0)
        params = M.init_moe(jax.random.PRNGKey(0), 32, cfg, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
        out, aux = jax.jit(lambda x: M.moe_ffn(x, params, cfg))(x)
        want, aux2 = jax.jit(lambda x: M.moe_ffn_dense_oracle(x, params, cfg))(x)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   atol=2e-5, rtol=2e-5)
        assert float(aux) == pytest.approx(float(aux2))

    def test_router_bias_changes_selection_not_weights(self):
        """Aux-loss-free balancing (kimi): bias shifts top-k choice, but
        combine weights still come from the unbiased softmax."""
        cfg = MoEConfig(num_experts=4, top_k=1, num_shared=0, d_ff_expert=16,
                        router_bias=True)
        params = M.init_moe(jax.random.PRNGKey(0), 8, cfg, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 8))
        ids0, w0, _ = M.route(x, params, cfg)
        params2 = dict(params)
        params2["router_bias"] = jnp.asarray([100.0, 0.0, 0.0, 0.0])
        ids1, w1, _ = M.route(x, params2, cfg)
        assert (np.asarray(ids1) == 0).all()       # bias forces expert 0
        probs_all = jax.nn.softmax(
            jnp.einsum("bsd,de->bse", x, params["router"]), -1
        )
        np.testing.assert_allclose(
            np.asarray(w1[..., 0]), np.asarray(probs_all[..., 0] / probs_all[..., 0]),
            atol=1e-6,
        )  # top-1 weights renormalize to 1

    def test_aux_loss_penalizes_imbalance(self):
        cfg = MoEConfig(num_experts=4, top_k=1, num_shared=0, d_ff_expert=16)
        d = 8
        params = M.init_moe(jax.random.PRNGKey(0), d, cfg, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, d))
        _, _, aux_balanced = M.route(x, params, cfg)
        params_skew = dict(params)
        params_skew["router"] = params["router"] * 0.0 + jnp.asarray(
            [[10.0, 0, 0, 0]] * d
        )
        _, _, aux_skew = M.route(x, params_skew, cfg)
        assert float(aux_skew) > float(aux_balanced)
