"""Property tests for the distributed plane's wire codec (`repro.dist.wire`).

Satellite contract (ISSUE 8): encode→decode of ``extract_rows`` canonical
row payloads and checkpoint SNAPSHOT frames is **bit-exact** — for empty,
single-row, and forced-spill row sets, on both state backends — plus the
codec's defensive surface: magic/version validation, truncation, trailing
bytes, and byte-stream framing equivalence with Connection transport.
"""

import io

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.dist import wire
from repro.keyed import KeyedWindowEngine, WindowSpec, synthetic_keyed_items

SPEC = WindowSpec("tumbling", size=7, lateness=3, late_policy="side")
NUM_SLOTS = 12


def _engine(backend, n_items, *, seed=0, n_workers=3):
    """A keyed engine with real standing state; ``capacity=4, max_probes=2``
    under ``device_table`` forces spill-tier rows once enough keys land."""
    kw = dict(capacity=4, max_probes=2) if backend == "device_table" else {}
    eng = KeyedWindowEngine(
        SPEC, num_slots=NUM_SLOTS, n_workers=n_workers, backend=backend, **kw
    )
    if n_items:
        items = synthetic_keyed_items(
            n_items, num_keys=max(2, n_items // 2), disorder=3, seed=seed
        )
        eng.process_chunk(
            {"key": items["key"], "value": items["value"], "ts": items["ts"]}
        )
    return eng


def _assert_cols_equal(a, b):
    assert set(a) == set(b)
    for k in a:
        assert a[k].dtype == b[k].dtype, k
        assert np.array_equal(a[k], b[k]), k


class TestRowPayloadRoundTrip:
    """encode→decode of the canonical sorted-row migration payload."""

    @settings(max_examples=12, deadline=None)
    @given(
        st.sampled_from(["host", "device_table"]),
        st.sampled_from([0, 1, 40]),   # empty / single-row / forced-spill
        st.integers(0, 5),
    )
    def test_extract_rows_payload_bit_exact(self, backend, n_items, seed):
        eng = _engine(backend, n_items, seed=seed)
        rows = eng.extract_rows(np.arange(NUM_SLOTS, dtype=np.int64))
        if n_items >= 40 and backend == "device_table":
            # the point of the tiny table: this row set crossed the spill
            # tier, so the payload exercises both physical tiers
            assert eng.table.stats.spilled > 0 or eng.table.stats.evicted >= 0
        cols = wire.rows_to_cols(rows)
        ftype, meta, out = wire.decode(wire.encode(wire.ROWS, None, cols))
        assert ftype == wire.ROWS and meta == {}
        _assert_cols_equal(cols, out)
        back = wire.cols_to_rows(out)
        for orig, rt in zip(rows, back):
            assert orig.dtype == np.int64 and rt.dtype == np.int64
            assert np.array_equal(orig, rt)

    @settings(max_examples=10, deadline=None)
    @given(
        st.sampled_from(["host", "device_table"]),
        st.sampled_from([0, 1, 40]),
        st.integers(0, 4),
    )
    def test_snapshot_frame_bit_exact(self, backend, n_items, seed):
        """A checkpoint SNAPSHOT frame reconstructs the canonical engine
        snapshot exactly: every scalar, every column, every dtype."""
        eng = _engine(backend, n_items, seed=seed)
        snap = eng.snapshot()
        meta, cols = wire.snapshot_to_frame(snap)
        buf = wire.encode(wire.SNAPSHOT, meta, cols)
        ftype, m2, c2 = wire.decode(buf)
        assert ftype == wire.SNAPSHOT
        rebuilt = wire.frame_to_snapshot(m2, c2)
        assert set(rebuilt) == set(snap)
        for k in snap:
            a, b = np.asarray(snap[k]), np.asarray(rebuilt[k])
            assert a.dtype == b.dtype, k
            assert np.array_equal(a, b), k
        # and the frame is re-encodable to the identical bytes (stable order)
        m3, c3 = wire.snapshot_to_frame(rebuilt)
        assert wire.encode(wire.SNAPSHOT, m3, c3) == buf

    @settings(max_examples=10, deadline=None)
    @given(
        st.lists(st.integers(-(2 ** 62), 2 ** 62), max_size=16),
        st.integers(0, 2 ** 31 - 1),
    )
    def test_arbitrary_int64_columns_round_trip(self, vals, seed):
        """Adversarial values (negative keys, INT64-scale timestamps) are
        byte-transparent — the codec never reinterprets payloads."""
        rng = np.random.default_rng(seed)
        cols = {
            "a": np.asarray(vals, np.int64),
            "b": rng.integers(-(2 ** 62), 2 ** 62, size=len(vals)),
            "tbl": rng.integers(0, 100, size=7).astype(np.int32),
            "f": rng.standard_normal(3),
            "m": rng.integers(0, 2, size=5).astype(bool),
        }
        meta = {"x": 1, "name": "t", "none": None}
        ftype, m2, c2 = wire.decode(wire.encode(wire.STEP, meta, cols))
        assert ftype == wire.STEP and m2 == meta
        _assert_cols_equal(cols, c2)


class TestFramingAndVersioning:
    def test_stream_framing_equals_connection_framing(self):
        """write_frame/read_frame (u32-prefixed byte stream) carry the
        identical frame bytes as Connection send/recv."""
        cols = {"key": np.arange(5, dtype=np.int64)}
        buf = io.BytesIO()
        n = wire.write_frame(buf, wire.INGEST, {"rows": 5}, cols)
        assert n == buf.tell() == 4 + len(wire.encode(wire.INGEST,
                                                      {"rows": 5}, cols))
        buf.seek(0)
        ftype, meta, out = wire.read_frame(buf)
        assert ftype == wire.INGEST and meta == {"rows": 5}
        assert np.array_equal(out["key"], cols["key"])

    def test_bad_magic_rejected(self):
        frame = bytearray(wire.encode(wire.OK))
        frame[:4] = b"XXXX"
        with pytest.raises(wire.WireError, match="magic"):
            wire.decode(bytes(frame))

    def test_unknown_version_rejected(self):
        frame = bytearray(wire.encode(wire.OK))
        frame[4] = wire.VERSION + 1
        with pytest.raises(wire.WireError, match="version"):
            wire.decode(bytes(frame))

    def test_truncation_rejected(self):
        frame = wire.encode(
            wire.ROWS, {"rows": 3}, {"key": np.arange(3, dtype=np.int64)}
        )
        for cut in (3, wire.HEADER_BYTES + 1, len(frame) - 1):
            with pytest.raises(wire.WireError):
                wire.decode(frame[:cut])

    def test_trailing_bytes_rejected(self):
        frame = wire.encode(wire.OK, {"n": 1})
        with pytest.raises(wire.WireError, match="trailing"):
            wire.decode(frame + b"\x00")

    def test_unsupported_dtype_rejected(self):
        with pytest.raises(wire.WireError, match="dtype"):
            wire.encode(wire.STEP, None, {"c": np.arange(3, dtype=np.int16)})

    def test_non_1d_rejected(self):
        with pytest.raises(wire.WireError, match="1-D"):
            wire.encode(wire.STEP, None, {"c": np.zeros((2, 2), np.int64)})

    def test_truncated_stream_prefix_rejected(self):
        with pytest.raises(wire.WireError, match="prefix"):
            wire.read_frame(io.BytesIO(b"\x01\x02"))

    def test_frame_names_cover_all_types(self):
        """Every declared frame type has a human-readable name (the black
        box and error messages rely on it)."""
        for t in (wire.HELLO, wire.ATTACH, wire.STEP, wire.STEP_OUT,
                  wire.SNAPSHOT_REQ, wire.SNAPSHOT, wire.EXTRACT, wire.ROWS,
                  wire.INGEST, wire.APPLY, wire.HEALTH_REQ, wire.HEALTH,
                  wire.DETACH, wire.SHUTDOWN, wire.CRASH, wire.OK, wire.ERR):
            assert t in wire.FRAME_NAMES


class TestVectoredSend:
    """The zero-copy send path (`encode_parts` + `os.writev`) is a pure
    transport optimization: the bytes on the wire are identical to the
    legacy single-buffer encoding, for every frame shape."""

    @settings(max_examples=12, deadline=None)
    @given(
        st.sampled_from(["host", "device_table"]),
        st.sampled_from([0, 1, 40]),
        st.integers(0, 5),
    )
    def test_encode_parts_joins_to_encode(self, backend, n_items, seed):
        eng = _engine(backend, n_items, seed=seed)
        meta, cols = wire.snapshot_to_frame(eng.snapshot())
        whole = wire.encode(wire.SNAPSHOT, meta, cols)
        parts = wire.encode_parts(wire.SNAPSHOT, meta, cols)
        assert b"".join(parts) == whole

    def test_writev_send_byte_identical_over_pipe(self):
        """`wire.send` on a real Connection produces exactly the bytes the
        peer's `recv_bytes` + `decode` expects — i.e. the vectored path
        replicates Connection framing bit-for-bit."""
        import multiprocessing

        a, b = multiprocessing.Pipe()
        try:
            cols = {
                "key": np.arange(1000, dtype=np.int64),
                "flag": np.zeros(1000, np.bool_),
            }
            meta = {"seq": 42, "shard": 3}
            n = wire.send(a, wire.STEP, meta, cols)
            raw = b.recv_bytes()
            assert len(raw) == n
            assert raw == wire.encode(wire.STEP, meta, cols)
            ftype, rmeta, rcols = wire.decode(raw)
            assert ftype == wire.STEP and rmeta == meta
            _assert_cols_equal(rcols, {"key": cols["key"],
                                       "flag": cols["flag"]})
        finally:
            a.close()
            b.close()

    def test_send_without_fileno_falls_back(self):
        """A connection-like object with no file descriptor still works —
        the vectored path degrades to the single-buffer send."""

        class FakeConn:
            def __init__(self):
                self.sent = []

            def fileno(self):
                raise OSError("no fd")

            def send_bytes(self, b):
                self.sent.append(bytes(b))

        conn = FakeConn()
        cols = {"v": np.arange(7, dtype=np.int64)}
        n = wire.send(conn, wire.INGEST, {"rows": 7}, cols)
        assert conn.sent and len(conn.sent[0]) == n
        assert conn.sent[0] == wire.encode(wire.INGEST, {"rows": 7}, cols)

    def test_flags_round_trip(self):
        """Header flags survive encode→decode (the shm descriptor bit);
        decode exposes them without altering v1 compatibility."""
        frame = wire.encode(wire.OK, {"seq": 1}, flags=wire.FLAG_SHM)
        ftype, meta, cols = wire.decode(frame)
        assert ftype == wire.OK and meta == {"seq": 1} and cols == {}


class TestCrcTrailer:
    """The v2 CRC32 trailer (FLAG_CRC): end-to-end frame integrity with
    byte-exact v1 interop for plain frames."""

    def _frame(self, flags=wire.FLAG_CRC):
        return wire.encode(
            wire.STEP, {"seq": 9, "shard": 1},
            {"key": np.arange(6, dtype=np.int64),
             "tbl": np.arange(4, dtype=np.int32)},
            flags=flags,
        )

    def test_crc_frame_round_trips(self):
        frame = self._frame()
        assert frame[4] == 2  # CRC frames are labelled v2
        ftype, meta, cols, flags = wire.decode_ex(frame)
        assert ftype == wire.STEP and meta == {"seq": 9, "shard": 1}
        assert flags & wire.FLAG_CRC
        assert np.array_equal(cols["key"], np.arange(6))

    def test_plain_frames_stay_v1(self):
        """A CRC-off link emits byte-identical v1 frames — the old-peer
        interop half of the HELLO negotiation."""
        frame = self._frame(flags=0)
        assert frame[4] == 1
        ftype, meta, cols = wire.decode(frame)
        assert ftype == wire.STEP and meta == {"seq": 9, "shard": 1}

    def test_crc_flag_adds_exactly_trailer_bytes(self):
        assert (len(self._frame()) - len(self._frame(flags=0))
                == wire.CRC_BYTES)

    def test_every_byte_flip_detected(self):
        """No single flipped byte anywhere in a CRC frame decodes silently
        — header, meta, payload, and trailer are all covered."""
        frame = self._frame()
        for i in range(len(frame)):
            bad = bytearray(frame)
            bad[i] ^= 0xFF
            with pytest.raises(wire.WireError):
                wire.decode(bytes(bad))

    def test_payload_flip_is_retriable_corrupt_frame(self):
        """A transport-mangled payload raises CorruptFrame specifically —
        the coordinator's cue to retransmit rather than declare death."""
        frame = bytearray(self._frame())
        frame[wire.HEADER_BYTES + 3] ^= 0x01
        with pytest.raises(wire.CorruptFrame):
            wire.decode(bytes(frame))
        assert issubclass(wire.CorruptFrame, wire.WireError)

    def test_truncated_crc_trailer_rejected(self):
        header_only = self._frame()[:wire.HEADER_BYTES]
        with pytest.raises(wire.WireError, match="CRC"):
            wire.decode(header_only)


class TestHostileInput:
    """`decode`/`read_frame` against adversarial bytes: declared-length
    caps before allocation, and WireError (never a raw struct/json/numpy
    error) on any malformed input."""

    def test_read_frame_giant_prefix_capped(self):
        """A corrupt 4 GiB length prefix raises before any allocation."""
        stream = io.BytesIO(b"\xff\xff\xff\xff" + b"x" * 64)
        with pytest.raises(wire.WireError, match="cap"):
            wire.read_frame(stream)

    def test_read_frame_sub_header_length_rejected(self):
        stream = io.BytesIO(b"\x02\x00\x00\x00ab")
        with pytest.raises(wire.WireError, match="header"):
            wire.read_frame(stream)

    def test_declared_meta_len_capped(self):
        import struct
        frame = bytearray(wire.encode(wire.OK, {"a": 1}))
        struct.pack_into("<I", frame, 8, wire.MAX_META_BYTES + 1)
        with pytest.raises(wire.WireError, match="meta_len"):
            wire.decode(bytes(frame))

    def test_declared_ncols_capped(self):
        import struct
        frame = bytearray(wire.encode(wire.OK))
        struct.pack_into("<H", frame, 12, wire.MAX_COLS + 1)
        with pytest.raises(wire.WireError, match="ncols"):
            wire.decode(bytes(frame))

    def test_oversize_frame_rejected(self):
        buf = b"RKWP" + b"\x00" * wire.MAX_FRAME_BYTES
        with pytest.raises(wire.WireError, match="too large"):
            wire.decode(buf)

    def test_meta_non_object_rejected(self):
        import json
        meta_b = json.dumps([1, 2, 3]).encode()
        frame = (wire._HEADER.pack(wire.MAGIC, 1, wire.OK, 0,
                                   len(meta_b), 0, 0) + meta_b)
        with pytest.raises(wire.WireError, match="not an object"):
            wire.decode(frame)

    @settings(max_examples=60, deadline=None)
    @given(st.integers(0, 10 ** 9), st.booleans())
    def test_fuzz_truncate_or_flip_never_escapes_wire_error(self, n, crc):
        """Random truncation points and byte flips over a real frame: the
        decoder either succeeds (flip on a CRC-less frame may land in the
        payload) or raises a WireError subclass — never struct.error,
        UnicodeDecodeError, json.JSONDecodeError, or a numpy ValueError."""
        frame = bytearray(wire.encode(
            wire.STEP, {"seq": 3, "wm_ts": 12345},
            {"key": np.arange(9, dtype=np.int64),
             "f": np.linspace(0, 1, 5)},
            flags=wire.FLAG_CRC if crc else 0,
        ))
        if n % 2:
            frame = frame[: n % len(frame)]           # truncate
        else:
            frame[n % len(frame)] ^= 1 << (n % 8)      # bit flip
        try:
            wire.decode(bytes(frame))
        except wire.WireError:
            pass
