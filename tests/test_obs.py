"""Tests for the observability plane (repro.obs): tracer semantics,
histogram percentiles, Chrome trace export, instrumented runtime spans, and
gauge/engine-counter agreement."""

import json

import numpy as np
import pytest

from repro.keyed import FUSED_STAGES
from repro.keyed.runtime import KeyedWindowAdapter, synthetic_keyed_items
from repro.keyed.windows import WindowSpec
from repro.obs import (
    NULL_TRACER,
    Histogram,
    LogicalClock,
    MetricsRegistry,
    NullTracer,
    Tracer,
    chrome_trace,
    write_trace,
)
from repro.obs import report as report_mod
from repro.runtime.executor import StreamExecutor

# the runtime's fused-stage names are the single source of truth — a stage
# renamed there without updating detectors/gates should fail HERE, not in CI
STAGES = FUSED_STAGES


# ---------------------------------------------------------------------------
# tracer
# ---------------------------------------------------------------------------

class TestTracer:
    def test_nested_spans_depth_and_determinism(self):
        clk = LogicalClock()
        tr = Tracer(clock=clk)
        with tr.span("outer", m=3):
            clk.advance(1.0)
            with tr.span("inner"):
                clk.advance(2.0)
            clk.advance(0.5)
        assert [s.name for s in tr.spans] == ["inner", "outer"]  # exit order
        inner, outer = tr.spans
        assert (inner.t0, inner.t1, inner.depth) == (1.0, 3.0, 1)
        assert (outer.t0, outer.t1, outer.depth) == (0.0, 3.5, 0)
        assert outer.args == {"m": 3}
        # same thread -> same dense tid
        assert inner.tid == outer.tid == 0

    def test_total_by_name_sums_repeats(self):
        clk = LogicalClock()
        tr = Tracer(clock=clk)
        for _ in range(3):
            with tr.span("s"):
                clk.advance(2.0)
        assert tr.total_by_name() == {"s": (3, 6.0)}

    def test_instants_and_counters(self):
        clk = LogicalClock(t0=5.0)
        tr = Tracer(clock=clk)
        tr.instant("resize", n_old=2, n_new=4)
        tr.counter("queue", depth=7)
        assert tr.instants[0].t == 5.0
        assert tr.instants[0].args == {"n_old": 2, "n_new": 4}
        assert tr.counters[0].values == {"depth": 7}

    def test_bounded_buffer_counts_drops(self):
        clk = LogicalClock()
        tr = Tracer(clock=clk, max_events=2)
        for _ in range(5):
            with tr.span("s"):
                clk.advance(1.0)
        assert len(tr.spans) == 2 and tr.dropped == 3
        tr.reset()
        assert tr.spans == [] and tr.dropped == 0

    def test_drops_counted_per_event_kind(self):
        clk = LogicalClock()
        tr = Tracer(clock=clk, max_events=2, recorder=None)
        for _ in range(3):
            with tr.span("s"):
                clk.advance(1.0)
        for _ in range(2):
            tr.instant("i")
        tr.counter("c", v=1)
        assert tr.dropped_spans == 1
        assert tr.dropped_instants == 2
        assert tr.dropped_counters == 1
        assert tr.dropped == 4

    def test_export_drops_lands_in_registry_and_trace(self):
        clk = LogicalClock()
        tr = Tracer(clock=clk, max_events=1, recorder=None)
        for _ in range(3):
            with tr.span("s"):
                clk.advance(1.0)
        reg = MetricsRegistry()
        tr.export_drops(reg)
        assert reg.counter("obs.tracer.dropped_spans").value == 2
        assert reg.counter("obs.tracer.dropped_instants").value == 0
        # the export path refreshes the counters before snapshotting
        doc = chrome_trace(tr, registry=reg)
        assert doc["otherData"]["dropped_spans"] == 2
        counters = doc["otherData"]["metrics"]["counters"]
        assert counters["obs.tracer.dropped_spans"] == 2

    def test_null_tracer_is_inert_and_shared(self):
        nt = NullTracer()
        s1 = nt.span("x", a=1)
        s2 = NULL_TRACER.span("y")
        assert s1 is s2  # one shared singleton context manager
        with s1:
            pass
        nt.instant("e")
        nt.counter("c", v=1)
        assert nt.spans == [] and nt.total_by_name() == {}
        assert not nt.enabled
        # carries a usable clock for code that times itself via the tracer
        assert isinstance(nt.clock.now(), float)


# ---------------------------------------------------------------------------
# histogram percentiles
# ---------------------------------------------------------------------------

class TestHistogram:
    def test_percentiles_close_to_numpy(self):
        rng = np.random.default_rng(0)
        samples = rng.lognormal(mean=-6.0, sigma=1.0, size=20_000)
        h = Histogram(lo=1e-6, hi=1e3, bins_per_decade=8)
        for v in samples:
            h.record(float(v))
        for q in (0.50, 0.95, 0.99):
            exact = float(np.quantile(samples, q))
            approx = h.percentile(q)
            # log-bucket resolution: 8 bins/decade -> ~33% worst-case bucket
            # width; interpolation keeps it much tighter in practice
            assert approx == pytest.approx(exact, rel=0.35)
        assert h.count == len(samples)
        assert h.mean == pytest.approx(float(samples.mean()), rel=1e-9)

    def test_degenerate_and_out_of_range(self):
        h = Histogram(lo=1e-3, hi=1e3)
        assert h.percentile(0.5) is None  # empty
        for _ in range(10):
            h.record(42.0)
        assert h.percentile(0.0) == 42.0
        assert h.percentile(1.0) == 42.0
        # all-underflow resolves to the exact min, not a bucket edge
        h2 = Histogram(lo=1.0, hi=10.0)
        h2.record(1e-9)
        h2.record(1e-9)
        assert h2.percentile(0.5) == 1e-9
        # overflow resolves to the exact max
        h2.record(1e6)
        assert h2.percentile(1.0) == 1e6

    def test_rejects_bad_construction(self):
        with pytest.raises(ValueError):
            Histogram(lo=1.0, hi=0.5)
        with pytest.raises(ValueError):
            Histogram(bins_per_decade=0)

    def test_underflow_overflow_exposed(self):
        h = Histogram(lo=1.0, hi=100.0)
        for v in (0.01, 0.5, 2.0, 50.0, 1e4, 1e5):
            h.record(v)
        assert h.underflow == 2
        assert h.overflow == 2
        assert h.count == 6
        snap = h.snapshot()
        assert snap["underflow"] == 2 and snap["overflow"] == 2

    def test_record_many_bit_identical_to_loop(self):
        rng = np.random.default_rng(1)
        vals = rng.lognormal(mean=0.0, sigma=3.0, size=5000)
        vals[:5] = 0.0  # zeros land in underflow, same as record()
        a = Histogram(lo=1e-3, hi=1e3)
        b = Histogram(lo=1e-3, hi=1e3)
        for v in vals:
            a.record(float(v))
        b.record_many(vals)
        assert a.counts == b.counts
        assert a.count == b.count
        assert a.total == pytest.approx(b.total)
        assert (a.min, a.max) == (b.min, b.max)
        for q in (0.5, 0.95, 0.99):
            assert a.percentile(q) == b.percentile(q)

    def test_record_many_empty_is_noop(self):
        h = Histogram(lo=1e-3, hi=1e3)
        h.record_many(np.array([]))
        assert h.count == 0 and h.percentile(0.5) is None


# ---------------------------------------------------------------------------
# export
# ---------------------------------------------------------------------------

class TestExport:
    def _traced(self):
        clk = LogicalClock()
        tr = Tracer(clock=clk)
        with tr.span("chunk", m=4):
            clk.advance(0.25)
            with tr.span("route"):
                clk.advance(0.5)
        tr.instant("resize", n_old=1, n_new=2)
        tr.counter("queue", depth=3)
        return tr

    def test_chrome_trace_structure(self):
        tr = self._traced()
        reg = MetricsRegistry()
        reg.gauge("g").set(1.5)
        reg.counter("c").inc(7)
        doc = chrome_trace(tr, registry=reg)
        phs = [e["ph"] for e in doc["traceEvents"]]
        assert phs.count("X") == 2 and phs.count("i") == 1
        assert phs.count("C") == 1 and phs.count("M") >= 2
        xs = {e["name"]: e for e in doc["traceEvents"] if e["ph"] == "X"}
        # logical seconds scale to microseconds
        assert xs["route"]["ts"] == pytest.approx(0.25e6)
        assert xs["route"]["dur"] == pytest.approx(0.5e6)
        assert xs["chunk"]["dur"] == pytest.approx(0.75e6)
        # nesting is by timestamp containment on the same track
        assert xs["chunk"]["ts"] <= xs["route"]["ts"]
        assert (xs["route"]["ts"] + xs["route"]["dur"]
                <= xs["chunk"]["ts"] + xs["chunk"]["dur"])
        assert doc["otherData"]["metrics"]["gauges"]["g"] == 1.5
        assert doc["otherData"]["metrics"]["counters"]["c"] == 7
        json.dumps(doc)  # fully JSON-serializable

    def test_write_trace_and_report_render(self, tmp_path):
        path = tmp_path / "trace.json"
        write_trace(str(path), self._traced())
        doc = report_mod.load(str(path))
        md = report_mod.render(doc, title="t")
        assert "chunk" in md and "route" in md and "resize" in md
        out = tmp_path / "report.md"
        assert report_mod.main([str(path), "-o", str(out)]) == 0
        assert "Per-stage time breakdown" in out.read_text()

    def test_deterministic_under_logical_clock(self):
        a = json.dumps(chrome_trace(self._traced()), sort_keys=True)
        b = json.dumps(chrome_trace(self._traced()), sort_keys=True)
        assert a == b

    def test_report_handles_absent_anchor(self):
        doc = chrome_trace(self._traced())
        md = report_mod.render(doc, title="t", anchor="no_such_span")
        # graceful: a note instead of a crash or silent all-blank shares
        assert "no_such_span" in md and "absent" in md
        # the real anchor still yields share columns
        md2 = report_mod.render(doc, title="t", anchor="chunk")
        assert "absent" not in md2

    def test_report_cli_anchor_flag(self, tmp_path):
        path = tmp_path / "trace.json"
        write_trace(str(path), self._traced())
        out = tmp_path / "r.md"
        assert report_mod.main(
            [str(path), "-o", str(out), "--anchor", "route"]) == 0
        assert out.read_text()


# ---------------------------------------------------------------------------
# cross-source consistency: the runtime bus and the obs plane must agree
# ---------------------------------------------------------------------------

class TestCrossSourceConsistency:
    def test_bus_percentiles_match_obs_histogram(self):
        from repro.runtime.metrics import ChunkRecord, MetricsBus

        rng = np.random.default_rng(2)
        services = rng.lognormal(mean=-4.0, sigma=0.8, size=4000)
        bus = MetricsBus()
        # mirror of the bus's own histogram configuration
        h = Histogram(lo=1e-7, hi=1e4, bins_per_decade=8)
        t = 0.0
        for s in services:
            bus.record_chunk(ChunkRecord(t, t + float(s), m=64, n_workers=4,
                                         queue_depth=0))
            t += float(s)
        h.record_many(services)
        bp = bus.percentiles()
        for name, q in (("p50", 0.50), ("p95", 0.95), ("p99", 0.99)):
            # same implementation + same samples -> identical, not just close
            assert bp[name] == h.percentile(q)
            # and both within log-bucket resolution of the exact quantile
            assert bp[name] == pytest.approx(
                float(np.quantile(services, q)), rel=0.35)

    def test_health_gauges_exact_under_slo_instrumentation(self):
        from repro.obs.slo import SLOEngine, SLOSpec

        tr = Tracer()
        ad, ex = _run_fused(tr, n_chunks=8, chunk=256)
        reg = MetricsRegistry()
        engine = SLOEngine(tracer=tr)
        tracker = engine.add(SLOSpec(name="chunk_p99", objective=1.0))
        for s in (sp for sp in tr.spans if sp.name == "chunk"):
            tracker.observe(s.t1 - s.t0)
        tracker.evaluate()
        engine.export(reg)
        ad.export_health(reg)
        tr.export_drops(reg)
        snap = reg.snapshot()
        # the SLO plane shares the registry without perturbing the engine's
        # exact health accounting
        barrier = ex.snapshot_barrier()
        assert snap["counters"]["keyed.table.inserted"] == int(barrier["t_inserted"])
        assert snap["counters"]["keyed.late"] == int(barrier["late_count"])
        occ = ad._batched.per_shard_occupancy()
        assert snap["gauges"]["keyed.plane.resident_rows"] == int(occ.sum())
        # and the SLO gauges landed beside them in the same namespace
        assert "slo.chunk_p99.p" in snap["gauges"]
        assert "slo.chunk_p99.budget_remaining" in snap["gauges"]
        assert snap["counters"]["obs.tracer.dropped_spans"] == 0


# ---------------------------------------------------------------------------
# instrumented runtime
# ---------------------------------------------------------------------------

def _run_fused(tracer, *, degree=4, n_chunks=6, chunk=128, pipeline=False):
    spec = WindowSpec(kind="tumbling", size=8, lateness=2)
    ad = KeyedWindowAdapter(spec, num_slots=64, backend="device_table",
                            capacity=256, ttl=64)
    ex = StreamExecutor(ad, degree=degree, chunk_size=chunk, tracer=tracer,
                        pipeline=pipeline)
    items = synthetic_keyed_items(chunk * n_chunks, num_keys=512,
                                  disorder=2, seed=3)
    ex.run([items[i * chunk:(i + 1) * chunk] for i in range(n_chunks)],
           schedule={3: degree * 2})
    return ad, ex


class TestInstrumentedRuntime:
    def test_fused_run_emits_all_stage_spans(self):
        tr = Tracer()
        ad, ex = _run_fused(tr)
        names = tr.total_by_name()
        for stage in STAGES:
            assert stage in names, f"missing stage span {stage}"
        assert names["chunk"][0] == 6
        # the schedule's resize produced a span and an instant event
        assert "resize" in names
        assert any(i.name == "resize" for i in tr.instants)
        # adapter got re-pointed at the executor's tracer
        assert ad.tracer is tr

    def test_stage_spans_nest_inside_chunk_spans(self):
        tr = Tracer()
        _run_fused(tr)
        chunks = [s for s in tr.spans if s.name == "chunk"]
        for s in tr.spans:
            if s.name in STAGES:
                assert s.depth >= 1
                assert any(c.t0 <= s.t0 and s.t1 <= c.t1 for c in chunks)

    def test_stage_coverage_of_chunk_time(self):
        tr = Tracer()
        _run_fused(tr, n_chunks=8, chunk=256)
        tb = tr.total_by_name()
        stage_total = sum(tb[s][1] for s in STAGES if s in tb)
        chunk_total = tb["chunk"][1]
        assert 0.5 <= stage_total / chunk_total <= 1.0

    def test_pipeline_prepare_gets_its_own_thread_track(self):
        tr = Tracer()
        _run_fused(tr, pipeline=True)
        prepares = [s for s in tr.spans if s.name == "prepare"]
        assert prepares
        main_tid = [s for s in tr.spans if s.name == "chunk"][0].tid
        assert all(s.tid != main_tid for s in prepares)

    def test_untraced_run_is_bit_identical(self):
        spec = WindowSpec(kind="tumbling", size=8, lateness=2)
        outs = []
        for tracer in (None, Tracer()):
            ad = KeyedWindowAdapter(spec, num_slots=64,
                                    backend="device_table", capacity=256)
            ex = StreamExecutor(ad, degree=4, chunk_size=128, tracer=tracer)
            items = synthetic_keyed_items(512, num_keys=256, seed=7)
            outs.append(ex.run([items[i * 128:(i + 1) * 128]
                                for i in range(4)]))
        for a, b in zip(*outs):
            for ch in ("emissions", "late", "early"):
                for k in a[ch]:
                    np.testing.assert_array_equal(a[ch][k], b[ch][k])

    def test_health_gauges_match_engine_counters_exactly(self):
        tr = Tracer()
        ad, ex = _run_fused(tr, n_chunks=8, chunk=256)
        reg = MetricsRegistry()
        ad.export_health(reg)
        snap = reg.snapshot()
        # per-shard device-tier occupancy == the batched plane's row counts
        occ = ad._batched.per_shard_occupancy()
        n_w = ex.degree
        for w in range(n_w):
            assert snap["gauges"][f"keyed.shard{w}.occupancy"] == int(occ[w])
            assert snap["gauges"][f"keyed.shard{w}.resident_rows"] == int(occ[w])
            assert snap["gauges"][f"keyed.shard{w}.spill_rows"] == \
                ad.shards[w].store.num_rows()
        assert snap["gauges"]["keyed.plane.resident_rows"] == int(occ.sum())
        # counters == the exact sums the barrier snapshot serializes
        barrier = ex.snapshot_barrier()
        assert snap["counters"]["keyed.table.inserted"] == int(barrier["t_inserted"])
        assert snap["counters"]["keyed.table.hits"] == int(barrier["t_hits"])
        assert snap["counters"]["keyed.table.spilled"] == int(barrier["t_spilled"])
        assert snap["counters"]["keyed.table.evicted"] == int(barrier["t_evicted"])
        assert snap["counters"]["keyed.late"] == int(barrier["late_count"])

    def test_probe_distances_are_consistent(self):
        ad, _ = _run_fused(Tracer(), n_chunks=8, chunk=256)
        healths = ad._batched.per_shard_health()
        for w, h in enumerate(healths):
            t = ad.shards[w].table
            assert h["occupancy"] == t.occupancy
            th = t.health()
            assert h["probe_mean"] == pytest.approx(th["probe_mean"])
            assert h["probe_max"] == th["probe_max"]
