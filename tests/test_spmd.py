"""Run the SPMD pattern equivalence checks in a subprocess.

The subprocess sets ``--xla_force_host_platform_device_count=8``; running it
out-of-process keeps the main pytest session on 1 device (required for the
arch smoke tests)."""

import os
import subprocess
import sys

import pytest

_HERE = os.path.dirname(os.path.abspath(__file__))
_REPO = os.path.dirname(_HERE)


def _run(script: str, timeout: int = 600) -> subprocess.CompletedProcess:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(_REPO, "src") + os.pathsep + env.get(
        "PYTHONPATH", ""
    )
    return subprocess.run(
        [sys.executable, os.path.join(_HERE, script)],
        capture_output=True,
        text=True,
        env=env,
        timeout=timeout,
    )


def test_spmd_pattern_equivalence():
    proc = _run("spmd_checks.py")
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
    assert "ALL SPMD CHECKS PASSED" in proc.stdout
