"""Data pipeline, checkpointing, fault-tolerant driver, optimizer tests."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import repro.configs as configs
from repro.checkpoint import checkpoint as ckpt
from repro.data.pipeline import StreamState, SyntheticLM
from repro.ft.driver import TrainLoop
from repro.launch.cells import CellKnobs
from repro.launch.steps import build_train_step
from repro.launch.sharding import ShardingRules
from repro.models import transformer as T
from repro.optim import adamw


class TestData:
    def test_deterministic_and_position_indexed(self):
        d = SyntheticLM(vocab=100, seq_len=16, batch=4, seed=3)
        b1, b2 = d.batch_at(7), d.batch_at(7)
        np.testing.assert_array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))
        b3 = d.batch_at(8)
        assert not np.array_equal(np.asarray(b1["tokens"]), np.asarray(b3["tokens"]))

    def test_labels_are_shifted_tokens(self):
        d = SyntheticLM(vocab=100, seq_len=16, batch=2, seed=0)
        # regenerate the raw chunk: labels[t] == tokens[t+1] by construction
        from repro.data.pipeline import _chunk

        raw = _chunk(0, 5, 2, 17, 100)
        b = d.batch_at(5)
        np.testing.assert_array_equal(np.asarray(b["tokens"]), raw[:, :-1])
        np.testing.assert_array_equal(np.asarray(b["labels"]), raw[:, 1:])

    @given(st.integers(min_value=0, max_value=1000))
    @settings(max_examples=20, deadline=None)
    def test_tokens_in_vocab(self, pos):
        d = SyntheticLM(vocab=64, seq_len=8, batch=2, seed=1)
        b = d.batch_at(pos)
        assert int(b["tokens"].max()) < 64 and int(b["tokens"].min()) >= 0

    def test_stream_cursor(self):
        d = SyntheticLM(vocab=64, seq_len=8, batch=2)
        it = d.stream(StreamState(0))
        s1, b1 = next(it)
        assert s1.position == 1
        s2, b2 = next(it)
        assert s2.position == 2


class TestCheckpoint:
    def test_save_restore_roundtrip(self, tmp_path):
        tree = {"a": jnp.arange(12.0).reshape(3, 4), "b": {"c": jnp.int32(7)}}
        ckpt.save(str(tmp_path), 5, tree, metadata={"stream": {"position": 9}})
        assert ckpt.latest_step(str(tmp_path)) == 5
        restored, meta = ckpt.restore(str(tmp_path), 5, tree)
        np.testing.assert_array_equal(np.asarray(restored["a"]), np.asarray(tree["a"]))
        assert int(restored["b"]["c"]) == 7
        assert meta["stream"]["position"] == 9

    def test_latest_step_picks_newest_complete(self, tmp_path):
        tree = {"a": jnp.zeros(2)}
        ckpt.save(str(tmp_path), 1, tree)
        ckpt.save(str(tmp_path), 3, tree)
        os.makedirs(tmp_path / "step_9", exist_ok=True)  # incomplete (no manifest)
        assert ckpt.latest_step(str(tmp_path)) == 3

    def test_async_save(self, tmp_path):
        tree = {"a": jnp.ones(8)}
        t = ckpt.save(str(tmp_path), 2, tree, blocking=False)
        t.join(timeout=30)
        assert ckpt.latest_step(str(tmp_path)) == 2


class TestOptimizer:
    def test_adamw_decreases_quadratic(self):
        cfg = adamw.AdamWConfig(peak_lr=0.1, warmup_steps=1, total_steps=100,
                                weight_decay=0.0, schedule="constant")
        params = {"w": jnp.array([3.0, -2.0])}
        state = adamw.init_state(params)
        loss = lambda p: jnp.sum(p["w"] ** 2)
        for _ in range(50):
            g = jax.grad(loss)(params)
            params, state, m = adamw.apply_updates(params, g, state, cfg)
        assert float(loss(params)) < 0.1

    def test_clip_norm(self):
        cfg = adamw.AdamWConfig(clip_norm=1.0)
        g = {"w": jnp.full((4,), 100.0)}
        assert float(adamw.global_norm(g)) == pytest.approx(200.0)

    def test_wsd_schedule_shape(self):
        cfg = adamw.AdamWConfig(
            peak_lr=1.0, warmup_steps=10, total_steps=100, schedule="wsd",
            decay_frac=0.2,
        )
        lrs = [float(adamw.schedule(cfg, jnp.int32(s))) for s in (0, 5, 10, 50, 99)]
        assert lrs[0] == 0.0
        assert lrs[1] == pytest.approx(0.5)
        assert lrs[2] == pytest.approx(1.0)
        assert lrs[3] == pytest.approx(1.0)   # stable phase
        assert lrs[4] < 0.35                   # decay phase


def _tiny_setup(tmp_path, fail_at=None):
    cfg = configs.get("paper-synthetic").reduced()
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    opt_state = adamw.init_state(params)
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    knobs = CellKnobs(microbatches=2, remat=False, fsdp=False)
    rules = ShardingRules(mesh=mesh, dp_axes=("data",), fsdp_axis=None)
    opt_cfg = adamw.AdamWConfig(peak_lr=3e-3, warmup_steps=2, total_steps=1000,
                                schedule="constant")
    step = jax.jit(build_train_step(cfg, rules, knobs, opt_cfg=opt_cfg))
    data = SyntheticLM(vocab=cfg.padded_vocab, seq_len=16, batch=4,
                       microbatches=2, seed=0)
    loop = TrainLoop(
        train_step=step, data=data, ckpt_dir=str(tmp_path), ckpt_every=5,
        metric_flush_every=5, fail_at=fail_at,
    )
    return loop, params, opt_state


class TestFaultTolerance:
    def test_restart_is_bit_exact(self, tmp_path):
        """Crash at step 7, restart from step-5 checkpoint => identical final
        params to an uninterrupted run (deterministic stream cursor)."""
        loop1, p1, o1 = _tiny_setup(tmp_path / "a")
        params_clean, _, best_clean = loop1.run(p1, o1, 12, log=lambda *_: None)

        loop2, p2, o2 = _tiny_setup(tmp_path / "b", fail_at=7)
        params_ft, _, best_ft = loop2.run(p2, o2, 12, log=lambda *_: None)

        for a, b in zip(jax.tree.leaves(params_clean), jax.tree.leaves(params_ft)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_loss_decreases(self, tmp_path):
        loop, p, o = _tiny_setup(tmp_path)
        logs = []
        loop.run(p, o, 20, log=logs.append)
        losses = [float(l.split("loss ")[1].split(" ")[0]) for l in logs if "loss" in l]
        assert losses[-1] < losses[0]

    def test_best_tracker_monotone(self, tmp_path):
        from repro.ft.driver import BestTracker

        t = BestTracker()
        assert t.propose(5.0, 1)
        assert not t.propose(6.0, 2)  # non-monotone proposal discarded (S4)
        assert t.propose(4.0, 3)
        assert t.best == 4.0
