"""Property tests for the shared-memory column transport (`repro.dist.shm`).

The ring codec is the correctness-critical core of the zero-copy transport:
these tests drive it with randomized column sets through full round-trips
(both transport endpoints paired in-process over a real ``multiprocessing``
pipe), across ring wraparound, through generation reuse, and into the
capacity-exhaustion fallback — the properties the shard-host protocol
relies on.  Process-boundary coverage lives in ``tests/test_dist.py``
(the whole distributed suite runs over this transport).
"""

import multiprocessing

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.dist import wire
from repro.dist.shm import (
    DEFAULT_CAPACITY,
    ShmError,
    ShmRing,
    ShmTransport,
)

_DTYPES = ["<i8", "<i4", "<f8", "|b1", "|u1"]


def _col(dtype, values):
    if dtype == "|b1":
        return np.asarray([bool(v & 1) for v in values], "|b1")
    if dtype == "|u1":
        return np.asarray([v & 0xFF for v in values], "|u1")
    return np.asarray(values, np.dtype(dtype))


def _pair(capacity=DEFAULT_CAPACITY, zero_copy=()):
    """Both transport endpoints in one process over a real duplex pipe."""
    a, b = multiprocessing.Pipe()
    r_ab, r_ba = ShmRing.create(capacity), ShmRing.create(capacity)
    ta = ShmTransport(a, send_ring=r_ab, recv_ring=r_ba, zero_copy=zero_copy)
    tb = ShmTransport(b, send_ring=r_ba, recv_ring=r_ab, zero_copy=zero_copy)
    return ta, tb


class TestRingCodecRoundTrip:
    @settings(max_examples=30, deadline=None)
    @given(
        st.lists(
            st.sampled_from(_DTYPES).map(lambda d: (d,)),
            min_size=0, max_size=5,
        ),
        st.lists(st.integers(-(2**31), 2**31 - 1), min_size=0, max_size=40),
        st.integers(0, 2**20),
    )
    def test_round_trip(self, dtypes, values, tag):
        """Any mix of supported column dtypes — including the empty frame,
        a single empty column, and multi-column payloads — survives a
        send/recv round-trip bit-exactly, with meta intact and every
        payload byte through the ring."""
        ta, tb = _pair()
        try:
            cols = {
                f"c{i}": _col(d, values) for i, (d,) in enumerate(dtypes)
            }
            meta = {"tag": tag, "n": len(values)}
            piped, shm = ta.send(wire.STEP, meta, cols)
            ftype, rmeta, rcols = tb.recv()
            assert ftype == wire.STEP
            assert rmeta == meta
            assert set(rcols) == set(cols)
            for k in cols:
                assert rcols[k].dtype == np.dtype(_canon(cols[k].dtype))
                assert np.array_equal(rcols[k], cols[k])
            if cols:
                assert shm == sum(c.nbytes for c in cols.values())
            else:
                assert shm == 0  # a column-less frame is pure pipe
            assert piped > 0
        finally:
            ta.close()
            tb.close()

    def test_single_row_single_column(self):
        ta, tb = _pair()
        try:
            ta.send(wire.STEP, {"seq": 1}, {"key": np.asarray([7], "<i8")})
            _, meta, cols = tb.recv()
            assert meta == {"seq": 1}
            assert cols["key"].tolist() == [7]
            assert cols["key"].flags.owndata  # copy-on-map outside zero_copy
        finally:
            ta.close()
            tb.close()


def _canon(dt):
    return {"|b1": "|b1", "|u1": "|u1"}.get(dt.str, dt.str.replace(">", "<"))


class TestWraparoundAndReuse:
    @settings(max_examples=15, deadline=None)
    @given(
        st.integers(1, 64),
        st.lists(st.integers(1, 120), min_size=4, max_size=40),
    )
    def test_wraparound_many_frames(self, seed, sizes):
        """A ring far smaller than the cumulative traffic: spans wrap (with
        dead-tail padding) and every frame still round-trips bit-exactly.
        The strict request/reply release discipline means capacity only
        needs to cover frames in flight, not the stream."""
        rng = np.random.default_rng(seed)
        ta, tb = _pair(capacity=1024)
        try:
            for i, n in enumerate(sizes):
                arr = rng.integers(-(2**40), 2**40, n).astype("<i8")
                piped, shm = ta.send(wire.STEP, {"i": i}, {"v": arr})
                _, meta, cols = tb.recv()
                assert meta["i"] == i
                assert np.array_equal(cols["v"], arr)
                if shm == 0:
                    # exhaustion fallback can only trigger when the span
                    # genuinely cannot fit alongside in-flight bytes
                    assert arr.nbytes + 8 > 1024 - 8
        finally:
            ta.close()
            tb.close()

    def test_generation_reuse_detected(self):
        """A descriptor held across its span's release+overwrite must trip
        the generation check, never yield foreign bytes."""
        ring = ShmRing.create(256)
        reader = ShmRing.attach(ring.name)
        try:
            g0 = ring.push([b"x" * 200])
            assert g0 is not None
            assert bytes(reader.view(g0, 200)) == b"x" * 200
            reader.release(g0, 200)
            g1 = ring.push([b"y" * 200])  # wraps onto g0's storage
            assert g1 is not None and g1 != g0
            with pytest.raises(ShmError):
                reader.view(g0, 200)  # stale generation
            assert bytes(reader.view(g1, 200)) == b"y" * 200
        finally:
            reader.close()
            ring.close()

    def test_zero_copy_views_and_fifo_release(self):
        """Zero-copy frame types map ring memory directly (no copy), stay
        valid across multiple held spans, and are released together at the
        next recv — after which the capacity is writable again."""
        ta, tb = _pair(capacity=4096, zero_copy=(wire.STEP,))
        try:
            a1 = np.arange(64, dtype="<i8")
            a2 = np.arange(64, 128, dtype="<i8")
            ta.send(wire.STEP, {"i": 1}, {"v": a1})
            ta.send(wire.STEP, {"i": 2}, {"v": a2})
            _, _, c1 = tb.recv()
            assert not c1["v"].flags.owndata  # a genuine ring view
            _, _, c2 = tb.recv()  # holds BOTH spans: FIFO release covers c1
            assert np.array_equal(c1["v"], a1)
            assert np.array_equal(c2["v"], a2)
            tb.release_held()
            # the released space is reusable: this span only fits because
            # release_held returned both held spans (and the wrap padding)
            # to the writer
            big = np.arange(125, dtype="<i8")
            piped, shm = ta.send(wire.STEP, {"i": 3}, {"v": big})
            assert shm == big.nbytes
            _, _, c3 = tb.recv()
            assert np.array_equal(c3["v"], big)
        finally:
            ta.close()
            tb.close()


class TestFallback:
    def test_exhaustion_falls_back_to_pipe(self):
        """A payload larger than the ring ships inline over the pipe —
        degraded, never blocked or dropped — and the receiver decodes it
        with the same call."""
        ta, tb = _pair(capacity=1024)
        try:
            big = np.arange(4096, dtype="<i8")  # 32 KiB >> 1 KiB ring
            piped, shm = ta.send(wire.STEP, {"big": True}, {"v": big})
            assert shm == 0 and piped > big.nbytes
            ftype, meta, cols = tb.recv()
            assert ftype == wire.STEP and meta == {"big": True}
            assert np.array_equal(cols["v"], big)
            assert ta.piped_frames == 1 and ta.shm_frames == 0
            # and the ring keeps working for frames that do fit
            small = np.arange(16, dtype="<i8")
            piped, shm = ta.send(wire.STEP, {"big": False}, {"v": small})
            assert shm == small.nbytes
            _, _, cols = tb.recv()
            assert np.array_equal(cols["v"], small)
            assert ta.shm_frames == 1
        finally:
            ta.close()
            tb.close()

    def test_ringless_transport_is_plain_pipe(self):
        a, b = multiprocessing.Pipe()
        ta, tb = ShmTransport(a), ShmTransport(b)
        try:
            arr = np.arange(10, dtype="<i8")
            piped, shm = ta.send(wire.STEP, {"x": 1}, {"v": arr})
            assert shm == 0 and piped > 0
            ftype, meta, cols = tb.recv()
            assert ftype == wire.STEP and meta == {"x": 1}
            assert np.array_equal(cols["v"], arr)
        finally:
            ta.close()
            tb.close()

    def test_descriptor_without_ring_raises(self):
        """A shm descriptor arriving at a ring-less receiver is a protocol
        violation (the sender may only use the ring after the HELLO caps
        negotiation) and must fail loudly."""
        a, b = multiprocessing.Pipe()
        ring = ShmRing.create(1024)
        ta = ShmTransport(a, send_ring=ring)
        tb = ShmTransport(b)  # no recv ring attached
        try:
            ta.send(wire.STEP, {}, {"v": np.arange(4, dtype="<i8")})
            with pytest.raises(ShmError):
                tb.recv()
        finally:
            ta.close()
            tb.close()
