"""Per-architecture smoke tests: reduced same-family config, one
forward/train step + one prefill/decode step on CPU (1 device), asserting
output shapes and no NaNs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.models import transformer as T
from repro.models.config import shape_applicable, ALL_SHAPES

ARCHS = configs.names()


def make_batch(cfg, batch=2, seq=32, key=0):
    k = jax.random.PRNGKey(key)
    b = {
        "tokens": jax.random.randint(k, (batch, seq), 0, cfg.vocab_size),
        "labels": jax.random.randint(k, (batch, seq), 0, cfg.vocab_size),
    }
    if cfg.num_prefix_embeds:
        fd = cfg.frontend_dim or cfg.d_model
        b["prefix_embeds"] = jax.random.normal(
            k, (batch, cfg.num_prefix_embeds, fd), jnp.float32
        )
    if cfg.encoder_layers:
        fd = cfg.frontend_dim or cfg.d_model
        b["src_embeds"] = jax.random.normal(k, (batch, seq // 2, fd), jnp.float32)
    return b


@pytest.fixture(scope="module", params=ARCHS)
def arch_setup(request):
    cfg = configs.get(request.param).reduced()
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    return request.param, cfg, params


class TestArchSmoke:
    def test_full_config_matches_assignment(self, arch_setup):
        name, _, _ = arch_setup
        full = configs.get(name)
        table = {
            "codeqwen1_5_7b": (32, 4096, 32, 32, 13440, 92416),
            "gemma2_27b": (46, 4608, 32, 16, 36864, 256000),
            "minicpm_2b": (40, 2304, 36, 36, 5760, 122753),
            "granite_8b": (36, 4096, 32, 8, 14336, 49152),
            "kimi_k2_1t_a32b": (61, 7168, 64, 8, None, 163840),
            "deepseek_moe_16b": (28, 2048, 16, 16, None, 102400),
            "paligemma_3b": (18, 2048, 8, 1, 16384, 257216),
            "seamless_m4t_medium": (12, 1024, 16, 16, 4096, 256206),
            "mamba2_780m": (48, 1536, 0, 0, 0, 50280),
            "jamba_1_5_large_398b": (72, 8192, 64, 8, 24576, 65536),
        }
        L, d, h, kv, ff, v = table[name]
        assert full.num_layers == L and full.d_model == d
        assert full.num_heads == h and full.num_kv_heads == kv
        if ff is not None:
            assert full.d_ff == ff
        assert full.vocab_size == v
        # moe cells
        if name == "kimi_k2_1t_a32b":
            assert full.moe.num_experts == 384 and full.moe.top_k == 8
        if name == "deepseek_moe_16b":
            assert full.moe.num_experts == 64 and full.moe.top_k == 6
            assert full.moe.num_shared == 2 and full.moe.d_ff_expert == 1408
        if name == "jamba_1_5_large_398b":
            assert full.moe.num_experts == 16 and full.moe.top_k == 2
            mixers = [s.mixer for s in full.unit]
            assert mixers.count("full") == 1 and mixers.count("mamba") == 7

    def test_train_step(self, arch_setup):
        name, cfg, params = arch_setup
        batch = make_batch(cfg)

        @jax.jit
        def step(params, batch):
            loss, metrics = T.train_forward(params, batch, cfg)
            grads = jax.grad(lambda p: T.train_forward(p, batch, cfg)[0])(params)
            return loss, metrics, grads

        loss, metrics, grads = step(params, batch)
        assert np.isfinite(float(loss)), name
        assert float(loss) > 0
        gnorm = jnp.sqrt(
            sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in jax.tree.leaves(grads))
        )
        assert np.isfinite(float(gnorm)), name

    def test_prefill_decode_step(self, arch_setup):
        name, cfg, params = arch_setup
        B, S = 2, 32
        batch = make_batch(cfg, B, S)
        s_max = S + (cfg.num_prefix_embeds or 0) + 8
        caches = T.init_caches(cfg, B, s_max)
        logits, caches = jax.jit(
            lambda p, b, c: T.prefill_forward(p, b, cfg, c)
        )(params, batch, caches)
        assert logits.shape == (B, 1, cfg.vocab_size)
        assert np.isfinite(np.asarray(logits)).all(), name

        dec_batch = {"tokens": jnp.argmax(logits, -1).astype(jnp.int32)}
        if cfg.encoder_layers:
            # decoder needs encoder output at decode time
            from repro.models.transformer import _encode
            dec_batch["enc_out"] = _encode(params, batch["src_embeds"], cfg)
        prompt_len = S + (cfg.num_prefix_embeds or 0)
        logits2, caches2 = jax.jit(
            lambda p, b, c: T.decode_forward(p, b, cfg, c, prompt_len)
        )(params, dec_batch, caches)
        assert logits2.shape == (B, 1, cfg.vocab_size)
        assert np.isfinite(np.asarray(logits2)).all(), name

    def test_long500k_applicability_matches_design(self, arch_setup):
        name, cfg, _ = arch_setup
        full = configs.get(name)
        ok, reason = shape_applicable(full, ALL_SHAPES[3])
        if name in ("mamba2_780m", "jamba_1_5_large_398b"):
            assert ok
        else:
            assert not ok and reason
