"""SPMD equivalence checks for the state access patterns.

Executed as a SUBPROCESS by tests/test_spmd.py so the 8 placeholder host
devices never leak into the main pytest process (smoke tests and benches must
see 1 device).  Exits non-zero on the first failure.
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", "")
)

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import patterns, semantics  # noqa: E402


def make_mesh(n):
    return jax.make_mesh(
        (n,), ("workers",), axis_types=(jax.sharding.AxisType.Auto,)
    )


def check_partitioned():
    num_slots = 16
    for n_w in (2, 4, 8):
        mesh = make_mesh(n_w)
        pat = patterns.PartitionedState(
            f=lambda x, s: x * 2 + s,
            ns=lambda x, s: s + x,
            h=lambda x: (x.astype(jnp.int32) * 7) % num_slots,
            num_slots=num_slots,
        )
        xs = jnp.arange(64, dtype=jnp.int32)
        v0 = jnp.zeros((num_slots,), dtype=jnp.int32)
        ys_ref, v_ref = pat.reference(xs, v0)
        ys, v = pat.run(mesh, "workers", xs, v0)
        np.testing.assert_array_equal(np.asarray(v), np.asarray(v_ref))
        np.testing.assert_array_equal(np.asarray(ys), np.asarray(ys_ref))
    print("partitioned ok")


def check_partitioned_adaptivity():
    # state value invariant under reshard; handoff volume matches block math
    assert patterns.PartitionedState.handoff_volume(16, 4, 4) == 0
    v_up = patterns.PartitionedState.handoff_volume(16, 4, 8)
    v_down = patterns.PartitionedState.handoff_volume(16, 8, 4)
    assert v_up == v_down == 14  # slots 0-1 keep owner 0; the rest move
    assert 0 < patterns.PartitionedState.handoff_volume(64, 8, 16) < 64
    print("partitioned adaptivity ok")


def check_accumulator():
    pat = patterns.AccumulatorState(
        f=lambda x, view: x + view,       # reads the (possibly stale) view
        g=lambda x: x,
        combine=lambda a, b: a + b,
        zero=lambda: jnp.int32(0),
    )
    xs = jnp.arange(1, 65, dtype=jnp.int32)
    ys_ref, s_ref = pat.reference(xs)
    for n_w in (2, 4, 8):
        mesh = make_mesh(n_w)
        for flush_every in (1, 2, 4, 8):
            ys, s = pat.run(mesh, "workers", xs, flush_every=flush_every)
            # final state exact regardless of schedule ((+) assoc+comm)
            assert int(s) == int(s_ref), (n_w, flush_every, int(s), int(s_ref))
    # merge rule (adaptivity): s_i (+) s_j
    assert int(pat.merge_workers(jnp.int32(3), jnp.int32(4))) == 7
    assert int(pat.new_worker_state()) == 0
    print("accumulator ok")


def check_accumulator_flush1_views():
    # with flush_every=1 and n_w=1 the parallel run IS the serial fold
    pat = patterns.AccumulatorState(
        f=lambda x, view: view,
        g=lambda x: x,
        combine=lambda a, b: a + b,
        zero=lambda: jnp.int32(0),
    )
    xs = jnp.arange(1, 17, dtype=jnp.int32)
    ys_ref, s_ref = pat.reference(xs)
    mesh = make_mesh(1)
    ys, s = pat.run(mesh, "workers", xs, flush_every=1)
    np.testing.assert_array_equal(np.asarray(ys), np.asarray(ys_ref))
    assert int(s) == int(s_ref)
    print("accumulator flush1 ok")


def check_successive():
    pat = patterns.SuccessiveApproximationState(
        c=lambda x, s: x < s,
        s_prime=lambda x, s: jnp.minimum(x, s),
        direction="min",
    )
    rng = np.random.default_rng(0)
    xs = jnp.asarray(rng.random(64), dtype=jnp.float32)
    trace_ref, s_ref = pat.reference(xs, jnp.float32(np.inf))
    for n_w in (2, 4, 8):
        mesh = make_mesh(n_w)
        for sync_every in (1, 2, 8):
            trace, s = pat.run(
                mesh, "workers", xs, jnp.float32(np.inf), sync_every=sync_every
            )
            # min is assoc+comm: final global state exact
            assert float(s) == float(s_ref)
            # local traces are monotone non-increasing per worker
            tr = np.asarray(trace).reshape(n_w, -1)
            assert (np.diff(tr, axis=1) <= 1e-9).all()
    print("successive ok")


def check_separate():
    pat = patterns.SeparateTaskState(
        f=lambda x: x * x,
        s=lambda y, s: s * 31 + y,  # NON-commutative fold: order must be canonical
    )
    xs = jnp.arange(32, dtype=jnp.int32)
    ys_ref, trace_ref, s_ref = pat.reference(xs, jnp.int32(1))
    for n_w in (2, 4, 8):
        mesh = make_mesh(n_w)
        ys, trace, s = pat.run(mesh, "workers", xs, jnp.int32(1))
        np.testing.assert_array_equal(np.asarray(ys), np.asarray(ys_ref))
        np.testing.assert_array_equal(np.asarray(trace), np.asarray(trace_ref))
        assert int(s) == int(s_ref)
    assert pat.speedup_bound(100.0, 1.0) == 101.0
    print("separate ok")


def check_farm_map():
    from repro.core.farm import TaskFarm
    from jax import lax

    mesh = make_mesh(8)
    farm = TaskFarm(mesh, "workers")
    xs = jnp.arange(64, dtype=jnp.float32)
    ys = farm.map(lambda x: x * 3.0, xs)
    np.testing.assert_allclose(np.asarray(ys), np.asarray(xs) * 3.0)
    tot = farm.map(
        lambda x: x,
        xs,
        collector=lambda y, ax: lax.psum(jnp.sum(y), ax),
    )
    assert float(tot) == float(xs.sum())
    assert farm.n_workers == 8
    print("farm ok")


def check_moe_a2a():
    """Expert-parallel all_to_all MoE == dense oracle (no drops)."""
    from repro.launch.sharding import ShardingRules, use_rules
    from repro.models import moe as moe_lib
    from repro.models.config import MoEConfig

    mesh = jax.make_mesh(
        (4, 2), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
    )
    cfg = MoEConfig(num_experts=8, top_k=2, num_shared=1, d_ff_expert=32,
                    capacity_factor=8.0)  # big cf: no drops
    d = 16
    params = moe_lib.init_moe(jax.random.PRNGKey(0), d, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 4, d), jnp.float32)
    rules = ShardingRules(
        mesh=mesh, dp_axes=("data",), fsdp_axis=None, moe_a2a=True
    )
    out = jax.jit(
        lambda x: moe_lib.moe_ffn_a2a(x, params, cfg, activation="silu",
                                      rules=rules)
    )(x)[0]
    ref_out = jax.jit(
        lambda x: moe_lib.moe_ffn_dense_oracle(x, params, cfg)
    )(x)[0]
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref_out), atol=2e-5, rtol=2e-5
    )
    # gradients flow through the a2a dispatch
    g = jax.grad(
        lambda p: jnp.sum(
            moe_lib.moe_ffn_a2a(x, p, cfg, activation="silu", rules=rules)[0] ** 2
        )
    )(params)
    gnorm = sum(float(jnp.abs(l).sum()) for l in jax.tree.leaves(g))
    assert np.isfinite(gnorm) and gnorm > 0
    print("moe a2a ok")


if __name__ == "__main__":
    assert jax.device_count() == 8, jax.devices()
    check_moe_a2a()
    check_partitioned()
    check_partitioned_adaptivity()
    check_accumulator()
    check_accumulator_flush1_views()
    check_successive()
    check_separate()
    check_farm_map()
    print("ALL SPMD CHECKS PASSED")
