"""Regression tests for the incremental restack (ISSUE 8, satellite 3).

``BatchedWindowTable`` used to re-stack *every* shard slab into a fresh
``(n_w, capacity)`` plane on each resize — slab traffic proportional to
standing state, not to the rows the resize actually moved.  The plane is
now over-allocated with active-prefix views: survivors keep their segments
(identity-recognized), a shrink is a re-slice, a grow clears occupancy in
place, and only an allocation doubling copies bytes.  ``copied_bytes``
meters exactly those copies, so these tests pin in-place resizes to ZERO
slab traffic and compare against both ``migration_volume()`` (the wire-
accounted row handoff) and the bytes a full restack would have moved.
"""

import numpy as np

from repro.core import semantics
from repro.keyed import KeyedWindowAdapter, WindowSpec, synthetic_keyed_items
from repro.keyed.runtime import ROW_BYTES
from repro.keyed.table import BatchedWindowTable, DeviceWindowTable
from repro.runtime import StreamExecutor

NUM_SLOTS = 20
CHUNK = 16


def _triples(items):
    return [(int(r["key"]), int(r["value"]), int(r["ts"])) for r in items]


def _rows(d, cols=("key", "start", "end", "value", "count")):
    return [tuple(int(x) for x in row) for row in zip(*(d[k] for k in cols))]


def _executor(spec, *, degree=3, **table_kw):
    ad = KeyedWindowAdapter(
        spec, num_slots=NUM_SLOTS, backend="device_table", fused=True,
        **table_kw,
    )
    return ad, StreamExecutor(ad, degree=degree, chunk_size=CHUNK)


def _full_restack_bytes(plane):
    """What the old code moved on EVERY resize: every active segment of
    every column plane (6 int64 columns + 1 bool occupancy)."""
    return plane.n_shards * plane.capacity * (6 * 8 + 1)


class TestInPlaceResize:
    def test_grow_shrink_within_reserve_is_zero_copy(self):
        """Mid-stream grow (3->5->7) and shrink (7->2) within the reserved
        allocation: migration ships rows (metered by migration_volume), but
        the plane slabs move ZERO bytes — resize cost is strictly
        row-proportional, not standing-state-proportional."""
        spec = WindowSpec("tumbling", size=64, lateness=4)
        items = synthetic_keyed_items(CHUNK * 8, num_keys=14, disorder=2,
                                      seed=5)
        ad, ex = _executor(spec, capacity=64)
        chunks = [items[i: i + CHUNK] for i in range(0, len(items), CHUNK)]
        schedule = {2: 5, 4: 7, 6: 2}
        for i, c in enumerate(chunks):
            if i in schedule:
                ex.set_degree(schedule[i])
            ex.process(c)
            assert ad._batched is not None
            assert ad._batched.copied_bytes == 0

        vol = ex.metrics.migration_volume()
        assert vol["rows"] > 0                       # rows really moved
        assert vol["bytes"] == vol["rows"] * ROW_BYTES
        # the regression target: the old full restack would have moved the
        # whole standing plane on every resize — orders more than the rows
        assert ad._batched.copied_bytes == 0 < _full_restack_bytes(ad._batched)

    def test_survivor_segments_share_memory_across_resizes(self):
        """After a grow, every survivor shard's table columns are STILL
        views into the same backing plane (no copy), and a freshly joined
        shard's table is adopted in place — its ingest writes land directly
        in the plane segment."""
        spec = WindowSpec("tumbling", size=64, lateness=4)
        items = synthetic_keyed_items(CHUNK * 3, num_keys=10, disorder=2,
                                      seed=2)
        ad, ex = _executor(spec, capacity=32)
        for i in range(3):
            ex.process(items[i * CHUNK: (i + 1) * CHUNK])
        plane = ad._batched
        backing = plane._akey
        ex.set_degree(5)
        assert ad._batched is plane                  # same plane object
        assert plane._akey is backing                # no realloc happened
        for w, shard in enumerate(ad.shards):
            t = shard.table
            assert np.shares_memory(t.key, plane._akey), w
            assert np.shares_memory(t.occ, plane._aocc), w
        ex.set_degree(2)                             # shrink = prefix re-slice
        assert plane._akey is backing
        assert plane.copied_bytes == 0

    def test_fused_outputs_bit_exact_through_restacks(self):
        """The restacked plane is not just cheap — it is still the same
        plane: emissions through grow/shrink match the serial oracle."""
        spec = WindowSpec("tumbling", size=8, lateness=3, late_policy="side")
        items = synthetic_keyed_items(CHUNK * 7 + 5, num_keys=9, disorder=4,
                                      seed=17)
        ad, ex = _executor(spec, capacity=32, degree=2)
        chunks = [items[i: i + CHUNK] for i in range(0, len(items), CHUNK)]
        outs = ex.run(chunks, schedule={2: 5, 4: 3, 6: 6})
        o_em, o_open, _ = semantics.keyed_windows(
            "tumbling", _triples(items), **spec.oracle_kwargs(CHUNK)
        )
        assert [r for o in outs for r in _rows(o["emissions"])] == o_em
        assert ad._batched.copied_bytes == 0


class TestReallocAccounting:
    def test_realloc_charges_exactly_the_active_prefix(self):
        """Growing past the allocation is the ONE place slab bytes move —
        and every byte is charged to ``copied_bytes``."""
        cap = 16
        tables = [DeviceWindowTable(cap, max_probes=4) for _ in range(2)]
        plane = BatchedWindowTable(tables, reserve=2)
        assert plane.copied_bytes == 0
        # 6 int64 planes + 1 bool plane, 2 active segments each
        want = 2 * cap * (6 * 8 + 1)
        plane.restack(tables + [DeviceWindowTable(cap, max_probes=4)
                                for _ in range(3)])
        assert plane.n_shards == 5
        assert plane.copied_bytes == want
        # a further in-allocation shrink/grow is free again
        before = plane.copied_bytes
        plane.restack(plane._adopted[:3])
        plane.restack(plane._adopted[:3] + [DeviceWindowTable(cap,
                                                              max_probes=4)])
        assert plane.copied_bytes == before

    def test_foreign_nonempty_table_is_copied_and_charged(self):
        """The restore path hands the plane tables it has never adopted;
        non-empty ones must be copied in (and metered), empty ones are just
        an occupancy clear."""
        cap = 16
        tables = [DeviceWindowTable(cap, max_probes=4) for _ in range(2)]
        plane = BatchedWindowTable(tables, reserve=4)
        foreign = DeviceWindowTable(cap, max_probes=4)
        foreign.occ[3] = True
        foreign.key[3] = 42
        plane.restack(tables + [foreign])
        assert plane.copied_bytes == cap * (6 * 8 + 1)
        assert plane.key[2][3] == 42 and plane.occ[2][3]
