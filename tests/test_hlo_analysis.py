"""Unit tests for the trip-count-aware HLO cost analyzer (the roofline's
input).  A synthetic HLO module exercises the parser; a real compiled scan
validates trip multiplication end to end."""

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.launch import hlo_analysis as H

SYNTH = """\
HloModule test, num_partitions=4

%body.1 (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %p = (s32[], f32[8,16]{1,0}) parameter(0)
  %c1 = s32[] constant(1)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,16]{1,0} get-tuple-element(%p), index=1
  %dot.1 = f32[8,16]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ni = s32[] add(%i, %c1)
  ROOT %t = (s32[], f32[8,16]{1,0}) tuple(%ni, %dot.1)
}

%cond.1 (p2: (s32[], f32[8,16])) -> pred[] {
  %p2 = (s32[], f32[8,16]{1,0}) parameter(0)
  %bound = s32[] constant(5)
  %i2 = s32[] get-tuple-element(%p2), index=0
  ROOT %lt = pred[] compare(%i2, %bound), direction=LT
}

ENTRY %main (a: f32[8,16]) -> f32[8,16] {
  %a = f32[8,16]{1,0} parameter(0)
  %z = s32[] constant(0)
  %init = (s32[], f32[8,16]{1,0}) tuple(%z, %a)
  %w = (s32[], f32[8,16]{1,0}) while(%init), condition=%cond.1, body=%body.1
  %ar = f32[8,16]{1,0} all-reduce(%a), channel_id=1, replica_groups=[1,4]<=[4], to_apply=%body.1
  ROOT %out = f32[8,16]{1,0} get-tuple-element(%w), index=1
}
"""


class TestParser:
    def test_synthetic_module(self):
        s = H.analyze(SYNTH)
        # dot: 2*8*16*16 flops per iter (lhs contract dim 1 has size 16)
        # hmm: dot(%x [8,16], %x [8,16]) contracting {1}x{0}: invalid math but
        # the analyzer reads shapes: result [8,16], contract 16
        assert s.dot_flops == 5 * 2 * 8 * 16 * 16
        assert s.num_partitions == 4
        # all-reduce wire: 2 * bytes * (4-1)/4
        want = 2.0 * (8 * 16 * 4) * 0.75
        assert abs(s.collective_bytes - want) < 1e-6
        assert not s.warnings

    def test_shape_parsing(self):
        assert H._shape_bytes("f32[8,16]{1,0}") == 8 * 16 * 4
        assert H._shape_bytes("bf16[2,4]") == 2 * 4 * 2
        assert H._shape_bytes("(s32[], f32[4])") == 4 + 16
        assert H._shape_elems("f32[3,5]{1,0}") == 15

    def test_instr_parser_tuple_types_with_comments(self):
        line = ("  %w = (s32[], f32[4,4]{1,0}, /*index=2*/f32[2]{0}) "
                "while(%t), condition=%c, body=%b")
        i = H._parse_instr(line)
        assert i.opcode == "while"
        assert "condition=%c" in i.attrs and "body=%b" in i.attrs

    def test_real_scan_trip_count(self):
        def f(x, w):
            def body(c, _):
                return jnp.tanh(c @ w), None
            y, _ = lax.scan(body, x, None, length=7)
            return jnp.sum(y)

        xs = jax.ShapeDtypeStruct((32, 64), jnp.float32)
        ws = jax.ShapeDtypeStruct((64, 64), jnp.float32)
        c = jax.jit(f).lower(xs, ws).compile()
        s = H.analyze(c.as_text())
        assert s.dot_flops == 7 * 2 * 32 * 64 * 64
        # XLA's own count confirms the undercount we correct for
        cost = c.cost_analysis()
        if isinstance(cost, list):  # older JAX: one dict per partition
            cost = cost[0]
        assert cost["flops"] < s.dot_flops


class TestRooflineIntegration:
    def test_roofline_terms_from_record(self):
        from repro.core.analytics import Roofline

        r = Roofline(flops=2.56e15, hbm_bytes=2.56e13, collective_bytes=2.56e12,
                     chips=256)
        assert r.compute_s < r.memory_s < r.collective_s
        assert r.dominant == "collective"
        assert 0 < r.mfu_upper_bound(1e15) < 1
