"""Tests for the multi-process keyed state plane (`repro.dist`).

Acceptance contract (ISSUE 8): ``DistributedKeyedPlane`` — every engine
shard behind a real process boundary, driven over the wire protocol — is
**bit-exact** against :func:`repro.core.semantics.keyed_windows` AND
against the in-process plane across mid-stream grow/shrink at non-divisor
degrees; a killed worker process recovers through an *unmodified*
``Supervisor`` from the canonical snapshot (black box collected); and the
autoscaler chooses the process count through the same ``set_degree`` path
it uses for in-process shards.
"""

import os

import numpy as np
import pytest

from repro.core import semantics
from repro.dist import DistributedKeyedPlane
from repro.keyed import KeyedWindowAdapter, WindowSpec, synthetic_keyed_items
from repro.keyed.runtime import ROW_BYTES
from repro.runtime import (
    Autoscaler,
    BoundedSource,
    QueueDepthPolicy,
    StreamExecutor,
    Supervisor,
)

NUM_SLOTS = 20  # degrees 3, 6, 7 do not divide this
CHUNK = 16


def _triples(items):
    return [(int(r["key"]), int(r["value"]), int(r["ts"])) for r in items]


def _rows(d, cols=("key", "start", "end", "value", "count")):
    return [tuple(int(x) for x in row) for row in zip(*(d[k] for k in cols))]


def _emissions(outs, channel="emissions"):
    return [r for o in outs for r in _rows(o[channel])]


def _late(outs):
    return [
        r for o in outs for r in _rows(o["late"], ("key", "value", "ts",
                                                   "start"))
    ]


def _state_rows(state):
    return [
        tuple(int(x) for x in r)
        for r in zip(
            *(np.asarray(state[k]).tolist()
              for k in ("w_key", "w_start", "w_end", "w_value", "w_count"))
        )
    ]


def _chunks(items):
    return [items[i: i + CHUNK] for i in range(0, len(items), CHUNK)]


# ---------------------------------------------------------------------------
# the process-boundary plane vs the oracle AND the in-process plane
# ---------------------------------------------------------------------------

class TestDistributedPlaneBitExact:
    @pytest.mark.parametrize(
        "transport,spk,overlap",
        [
            ("pipe", 1, False),
            ("pipe", 1, True),
            ("shm", 1, True),
            ("shm", 2, True),
        ],
        ids=["pipe", "pipe-overlap", "shm-overlap", "shm-mux2-overlap"],
    )
    def test_grow_shrink_nondivisor_degrees_bit_exact(
        self, tmp_path, transport, spk, overlap
    ):
        """One executor over worker *processes*, one over in-process shards,
        same schedule with grow (2->3->7) and shrink (7->2) at degrees that
        do NOT divide num_slots=20: emissions, early firings, late records,
        migration row counts, barrier snapshots, and final state all match
        each other and the serial oracle — the process boundary changes
        transport, never semantics.  Parametrized over the pipe and
        shared-memory transports, shard-host multiplexing, and the
        overlapped scatter/gather pipeline (which must drain transparently
        at every scheduled resize)."""
        spec = WindowSpec("tumbling", size=8, lateness=3, late_policy="side",
                          early_every=2)
        items = synthetic_keyed_items(10 * CHUNK + 9, num_keys=12,
                                      disorder=4, seed=7)
        schedule = {2: 3, 5: 7, 8: 2}

        ref_ad = KeyedWindowAdapter(spec, num_slots=NUM_SLOTS,
                                    backend="device_table", capacity=64)
        ref_ex = StreamExecutor(ref_ad, degree=2, chunk_size=CHUNK)
        ref_outs = ref_ex.run(_chunks(items), schedule=schedule)

        ad = DistributedKeyedPlane(spec, num_slots=NUM_SLOTS,
                                   backend="device_table", capacity=64,
                                   prespawn=7, transport=transport,
                                   shards_per_host=spk,
                                   blackbox_dir=str(tmp_path / "bb"))
        try:
            ex = StreamExecutor(ad, degree=2, chunk_size=CHUNK,
                                pipeline=overlap)
            outs = ex.run(_chunks(items), schedule=schedule)

            # bit-exact vs the in-process fused plane, chunk by chunk
            assert len(outs) == len(ref_outs)
            for i, (o, r) in enumerate(zip(outs, ref_outs)):
                for ch in ("emissions", "early", "late"):
                    for k in o[ch]:
                        assert np.array_equal(o[ch][k], r[ch][k]), (i, ch, k)

            # ... and vs the serial oracle
            o_em, o_open, o_late, o_early = semantics.keyed_windows(
                "tumbling", _triples(items), **spec.oracle_kwargs(CHUNK)
            )
            assert _emissions(outs) == o_em
            assert _emissions(outs, "early") == o_early
            assert _late(outs) == o_late
            assert _state_rows(ex.state) == [tuple(t) for t in o_open]

            # the barrier snapshot merges shard processes into the one
            # canonical form the in-process plane produces
            s_ref = ref_ex.snapshot_barrier()
            s = ex.snapshot_barrier()
            assert set(s) == set(s_ref)
            for k in s_ref:
                assert np.array_equal(np.asarray(s[k]),
                                      np.asarray(s_ref[k])), k

            # migration accounting: the same rows moved, and the dist
            # plane's bytes are real *wire* bytes — payload plus a bounded
            # per-frame envelope (header + JSON meta), never a full restack
            vol_ref = ref_ex.metrics.migration_volume()
            vol = ex.metrics.migration_volume()
            assert vol["rows"] == vol_ref["rows"] > 0
            assert vol["slots"] == vol_ref["slots"]
            payload = vol["rows"] * ROW_BYTES
            assert payload <= vol["bytes"] <= payload + vol["handoffs"] * 7 * 512
            assert ad.wire_bytes["migration"] == vol["bytes"]
            assert ad.wire_bytes["step"] > 0
            # the transport split meters every byte exactly once: the pipe
            # transport never touches shared memory, the shm transport moves
            # the column payloads (the bulk of the traffic) through the rings
            assert ad.wire_bytes["piped"] > 0
            if transport == "shm":
                assert ad.wire_bytes["shm"] > 0
            else:
                assert ad.wire_bytes["shm"] == 0
        finally:
            ad.close()

    def test_overlap_actually_engages(self, tmp_path):
        """Guard against the pipeline silently degrading to synchronous:
        with ``pipeline=True`` and full-size chunks, every chunk after the
        first is scattered ahead (`step_ahead` returns True), and the
        outputs stay bit-exact vs the synchronous run."""
        spec = WindowSpec("tumbling", size=12, lateness=3, late_policy="side")
        items = synthetic_keyed_items(CHUNK * 6, num_keys=8, disorder=3,
                                      seed=5)

        def run(pipeline, counter=None):
            ad = DistributedKeyedPlane(
                spec, num_slots=NUM_SLOTS, prespawn=2, transport="shm",
                blackbox_dir=str(tmp_path / "bb"),
            )
            try:
                if counter is not None:
                    inner = ad.step_ahead

                    def counting(chunk, prepared=None):
                        ok = inner(chunk, prepared=prepared)
                        counter.append(ok)
                        return ok

                    ad.step_ahead = counting
                ex = StreamExecutor(ad, degree=2, chunk_size=CHUNK,
                                    pipeline=pipeline)
                outs = ex.run(_chunks(items))
                return outs, ex.state
            finally:
                ad.close()

        ref_outs, ref_state = run(False)
        hits = []
        outs, state = run(True, counter=hits)
        assert len(hits) == 5 and all(hits)  # chunks 1..5 scattered ahead
        assert _emissions(outs) == _emissions(ref_outs)
        assert _late(outs) == _late(ref_outs)
        assert _state_rows(state) == _state_rows(ref_state)


# ---------------------------------------------------------------------------
# real worker-process death -> supervisor recovery from canonical snapshot
# ---------------------------------------------------------------------------

class TestKilledWorkerRecovery:
    @pytest.mark.parametrize("transport,spares", [("pipe", 0), ("shm", 1)],
                             ids=["pipe", "shm-spare"])
    def test_killed_worker_recovers_through_supervisor(
        self, tmp_path, transport, spares
    ):
        """A CRASH frame makes shard 1's host dump its flight recorder and
        ``os._exit`` mid-stream — a *real* process death.  The unmodified
        Supervisor restores survivors from the canonical snapshot, the pool
        refills the hole (a promoted warm spare when ``spares>0``), and
        replay is bit-exact vs the oracle.  The dead worker's black box is
        collected.  Run under both transports — a death must also release
        the dead host's shared-memory rings."""
        spec = WindowSpec("tumbling", size=30, lateness=5, late_policy="side",
                          early_every=2)
        NCH = 6
        items = synthetic_keyed_items(CHUNK * NCH, num_keys=7, disorder=5,
                                      seed=3)
        src = BoundedSource(items)

        ad = DistributedKeyedPlane(spec, num_slots=10, backend="device_table",
                                   capacity=8, max_probes=2, ttl=4,
                                   prespawn=3, transport=transport,
                                   spares=spares,
                                   blackbox_dir=str(tmp_path / "bb"))
        try:
            ex = StreamExecutor(ad, degree=3, chunk_size=CHUNK)
            killed = {"done": False}

            def chunk_fn(i):
                if i == 3 and not killed["done"]:
                    killed["done"] = True
                    ad.kill_worker(1)  # real process death, mid-stream
                src.seek(i * CHUNK)
                return src.take(CHUNK)

            sup = Supervisor(ex, chunk_fn, num_chunks=NCH,
                             ckpt_dir=str(tmp_path / "ckpt"), ckpt_every=2)
            outs = sup.run()

            o_em, o_open, o_late, o_early = semantics.keyed_windows(
                "tumbling", _triples(items), **spec.oracle_kwargs(CHUNK)
            )
            ordered = [outs[i] for i in range(NCH)]
            assert _emissions(ordered) == o_em
            assert _emissions(ordered, "early") == o_early
            assert _late(ordered) == o_late
            assert _state_rows(ex.state) == [tuple(t) for t in o_open]

            kinds = [e.kind for e in sup.events]
            assert "failure" in kinds and "restore" in kinds
            assert "shrink" in kinds and "grow" in kinds
            # the dead worker's flight-recorder dump was collected
            assert ad.collected_blackboxes
            assert os.path.exists(ad.collected_blackboxes[0])
            if spares:
                # the hole was filled by promotion and the spare pool was
                # replenished asynchronously — failover never waits for a
                # process to boot
                assert len(ad._spares) == spares
                assert all(h is not None for h in ad._pool)
        finally:
            ad.close()


# ---------------------------------------------------------------------------
# the autoscaler chooses the *process* count
# ---------------------------------------------------------------------------

class TestAutoscalerOverProcesses:
    def test_autoscaler_scales_worker_processes(self, tmp_path):
        """The QueueDepthPolicy drives ``set_degree`` on the distributed
        plane exactly as it does in-process: a deep queue grows the number
        of worker *processes*, a drained queue shrinks it, and the stream
        stays bit-exact vs the oracle throughout."""
        spec = WindowSpec("tumbling", size=12, lateness=3, late_policy="side")
        items = synthetic_keyed_items(CHUNK * 6, num_keys=8, disorder=3,
                                      seed=11)

        ad = DistributedKeyedPlane(spec, num_slots=NUM_SLOTS, backend="host",
                                   prespawn=4,
                                   blackbox_dir=str(tmp_path / "bb"))
        try:
            ex = StreamExecutor(ad, degree=2, chunk_size=CHUNK)
            sc = Autoscaler(QueueDepthPolicy(), [2, 3, 4], cooldown_chunks=0)

            class _Q:
                high_watermark, low_watermark = 8, 1
                depth = 0

            outs = []
            chunks = _chunks(items)
            for i, c in enumerate(chunks):
                outs.append(ex.process(c))
                if i == 1:
                    _Q.depth = 99                      # pressure: scale up
                    d = sc.maybe_scale(ex, queue=_Q())
                    assert d is not None and d.applied
                    assert ad._active == 3
                if i == 3:
                    _Q.depth = 0                       # drained: scale down
                    d = sc.maybe_scale(ex, queue=_Q())
                    assert d is not None and d.applied
                    assert ad._active == 2
                    assert d.handoff_bytes >= d.handoff_rows * ROW_BYTES

            o_em, o_open, _ = semantics.keyed_windows(
                "tumbling", _triples(items), **spec.oracle_kwargs(CHUNK)
            )
            assert _emissions(outs) == o_em
            assert _state_rows(ex.state) == [tuple(t) for t in o_open]
        finally:
            ad.close()
