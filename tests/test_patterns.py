"""Unit + property tests for the state access pattern semantics (paper §4).

These run in the main pytest process on a single device; multi-worker SPMD
equivalence is covered by tests/test_spmd.py (subprocess with 8 host devices).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import patterns, semantics


def int_streams(min_size=1, max_size=64):
    return st.lists(
        st.integers(min_value=-1000, max_value=1000),
        min_size=min_size,
        max_size=max_size,
    )


# ---------------------------------------------------------------------------
# §4.1 serial
# ---------------------------------------------------------------------------

class TestSerial:
    def test_matches_paper_unrolled_definition(self):
        # ..., f(x1, ns(x0,s0)), f(x0, s0)
        f = lambda x, s: x * 10 + s
        ns = lambda x, s: s + x
        xs = jnp.array([1, 2, 3], dtype=jnp.int32)
        ys, s_final = semantics.serial(f, ns, xs, jnp.int32(100))
        assert ys.tolist() == [
            1 * 10 + 100,
            2 * 10 + 101,
            3 * 10 + 103,
        ]
        assert int(s_final) == 106

    @given(int_streams())
    @settings(max_examples=25, deadline=None)
    def test_run_equals_reference(self, data):
        pat = patterns.SerialState(f=lambda x, s: x - s, ns=lambda x, s: s + 2 * x)
        xs = jnp.asarray(data, dtype=jnp.int32)
        mesh = jax.make_mesh((1,), ("w",), axis_types=(jax.sharding.AxisType.Auto,))
        ys_ref, s_ref = pat.reference(xs, jnp.int32(0))
        ys, s = pat.run(mesh, "w", xs, jnp.int32(0))
        np.testing.assert_array_equal(np.asarray(ys), np.asarray(ys_ref))
        assert int(s) == int(s_ref)


# ---------------------------------------------------------------------------
# §4.2 partitioned
# ---------------------------------------------------------------------------

class TestPartitioned:
    @given(int_streams(), st.sampled_from([2, 4, 8]))
    @settings(max_examples=25, deadline=None)
    def test_per_slot_substream_is_serial(self, data, num_slots):
        """Partitioned semantics == running the serial pattern independently
        on each hash-class sub-stream (the paper's core §4.2 claim)."""
        f = lambda x, s: x + 3 * s
        ns = lambda x, s: s + x
        h = lambda x: jnp.abs(x.astype(jnp.int32) * 31 + 7) % num_slots
        xs = jnp.asarray(data, dtype=jnp.int32)
        v0 = jnp.arange(num_slots, dtype=jnp.int32)

        ys, v_final = semantics.partitioned(f, ns, h, xs, v0)

        hs = np.asarray(jax.vmap(h)(xs))
        for slot in range(num_slots):
            sub = xs[hs == slot]
            ys_slot, s_slot = semantics.serial(f, ns, sub, v0[slot])
            assert int(v_final[slot]) == int(s_slot)
            np.testing.assert_array_equal(
                np.asarray(ys)[hs == slot], np.asarray(ys_slot)
            )

    def test_pytree_state(self):
        # state per slot is a pytree, not just a scalar
        f = lambda x, s: s["a"] + x
        ns = lambda x, s: {"a": s["a"] + x, "n": s["n"] + 1}
        h = lambda x: x % 4
        xs = jnp.arange(16, dtype=jnp.int32)
        v0 = {"a": jnp.zeros(4, jnp.int32), "n": jnp.zeros(4, jnp.int32)}
        ys, v = semantics.partitioned(f, ns, h, xs, v0)
        assert v["n"].tolist() == [4, 4, 4, 4]
        assert int(v["a"].sum()) == int(xs.sum())

    def test_owner_block_distribution(self):
        pat = patterns.PartitionedState(
            f=lambda x, s: s, ns=lambda x, s: s, h=lambda x: x, num_slots=16
        )
        assert pat.slots_per_worker(4) == 4
        assert int(pat.owner(jnp.int32(0), 4)) == 0
        assert int(pat.owner(jnp.int32(15), 4)) == 3
        with pytest.raises(ValueError):
            pat.slots_per_worker(5)

    @given(
        st.integers(min_value=1, max_value=6).map(lambda k: 2**k),
        st.integers(min_value=0, max_value=3).map(lambda k: 2**k),
        st.integers(min_value=0, max_value=3).map(lambda k: 2**k),
    )
    @settings(max_examples=40, deadline=None)
    def test_handoff_volume_props(self, num_slots, n_old, n_new):
        if num_slots % n_old or num_slots % n_new:
            with pytest.raises(ValueError, match="num_slots"):
                patterns.PartitionedState.handoff_volume(num_slots, n_old, n_new)
            return
        v = patterns.PartitionedState.handoff_volume(num_slots, n_old, n_new)
        assert 0 <= v <= num_slots
        assert v == patterns.PartitionedState.handoff_volume(num_slots, n_new, n_old)
        if n_old == n_new:
            assert v == 0

    def test_adaptivity_math_validates(self):
        """§4.x hardening: ragged block sizes are an error, not a silent
        mis-count, in both ownership and handoff accounting."""
        pat = patterns.PartitionedState(
            f=lambda x, s: s, ns=lambda x, s: s, h=lambda x: x, num_slots=12
        )
        with pytest.raises(ValueError, match="does not divide"):
            pat.slots_per_worker(5)
        with pytest.raises(ValueError, match=">= 1"):
            pat.slots_per_worker(0)
        with pytest.raises(ValueError, match="n_old"):
            patterns.PartitionedState.handoff_volume(12, 5, 4)
        with pytest.raises(ValueError, match="n_new"):
            patterns.PartitionedState.handoff_volume(12, 4, 5)
        with pytest.raises(ValueError, match=">= 1"):
            patterns.PartitionedState.handoff_volume(12, 0, 4)
        # the valid cases still work
        assert patterns.PartitionedState.handoff_volume(12, 4, 4) == 0
        assert patterns.PartitionedState.handoff_volume(12, 2, 6) > 0


# ---------------------------------------------------------------------------
# §4.3 accumulator
# ---------------------------------------------------------------------------

class TestAccumulator:
    @given(int_streams())
    @settings(max_examples=25, deadline=None)
    def test_final_state_is_fold(self, data):
        xs = jnp.asarray(data, dtype=jnp.int32)
        ys, s = semantics.accumulator(
            f=lambda x, s: s,
            g=lambda x: x,
            combine=lambda a, b: a + b,
            xs=xs,
            s_zero=jnp.int32(0),
        )
        assert int(s) == int(np.asarray(data, dtype=np.int64).sum() % 2**32 % 2**32) or int(
            s
        ) == int(jnp.sum(xs))

    @given(int_streams(min_size=2))
    @settings(max_examples=25, deadline=None)
    def test_schedule_independence(self, data):
        """Associativity+commutativity => any permutation yields the same
        final state (the property that licenses parallelism in §4.3)."""
        xs = np.asarray(data, dtype=np.int32)
        perm = np.random.default_rng(0).permutation(len(xs))
        _, s1 = semantics.accumulator(
            lambda x, s: s, lambda x: x, lambda a, b: a + b, jnp.asarray(xs), jnp.int32(0)
        )
        _, s2 = semantics.accumulator(
            lambda x, s: s,
            lambda x: x,
            lambda a, b: a + b,
            jnp.asarray(xs[perm]),
            jnp.int32(0),
        )
        assert int(s1) == int(s2)

    def test_merge_rule(self):
        pat = patterns.AccumulatorState(
            f=lambda x, s: s,
            g=lambda x: x,
            combine=lambda a, b: a + b,
            zero=lambda: jnp.int32(0),
        )
        assert int(pat.merge_workers(jnp.int32(5), jnp.int32(7))) == 12
        assert int(pat.new_worker_state()) == 0

    @given(
        st.integers(min_value=-1000, max_value=1000),
        st.integers(min_value=-1000, max_value=1000),
        st.integers(min_value=-1000, max_value=1000),
    )
    @settings(max_examples=25, deadline=None)
    def test_merge_associativity_with_zero(self, a, b, c):
        """§4.3 adaptivity soundness: merge is associative and `zero()` is
        its identity — merging workers in any grouping (and merging in a
        fresh worker) cannot change the accumulated state."""
        pat = patterns.AccumulatorState(
            f=lambda x, s: s,
            g=lambda x: x,
            combine=lambda x, y: x + y,
            zero=lambda: jnp.int32(0),
        )
        sa, sb, sc = jnp.int32(a), jnp.int32(b), jnp.int32(c)
        lhs = pat.merge_workers(pat.merge_workers(sa, sb), sc)
        rhs = pat.merge_workers(sa, pat.merge_workers(sb, sc))
        assert int(lhs) == int(rhs)
        assert int(pat.merge_workers(sa, pat.new_worker_state())) == a
        assert int(pat.merge_workers(pat.new_worker_state(), sa)) == a


# ---------------------------------------------------------------------------
# §4.4 successive approximation
# ---------------------------------------------------------------------------

class TestSuccessiveApproximation:
    @given(
        st.lists(
            st.floats(min_value=0.0, max_value=1.0, allow_nan=False),
            min_size=1,
            max_size=64,
        )
    )
    @settings(max_examples=25, deadline=None)
    def test_trace_monotone_and_final_is_min(self, data):
        xs = jnp.asarray(data, dtype=jnp.float32)
        trace, s = semantics.successive_approximation(
            c=lambda x, s: x < s,
            s_prime=lambda x, s: jnp.minimum(x, s),
            xs=xs,
            s_init=jnp.float32(jnp.inf),
        )
        tr = np.asarray(trace)
        assert (np.diff(tr) <= 1e-9).all()
        assert float(s) == pytest.approx(float(np.min(np.float32(data))))

    def test_new_worker_state_joins_with_global(self):
        """§4.4 adaptivity: a worker added mid-run receives the committed
        global value (not s_init), so it can never propose a regression and
        never re-walks already-converged ground."""
        pat = patterns.SuccessiveApproximationState(
            c=lambda x, s: x < s,
            s_prime=lambda x, s: jnp.minimum(x, s),
            direction="min",
        )
        s_global = jnp.float32(0.25)
        joined = pat.new_worker_state(s_global)
        assert float(joined) == 0.25
        # pytree global state is handed over structurally intact
        tree = {"best": jnp.float32(0.5), "arg": jnp.int32(7)}
        joined_tree = pat.new_worker_state(tree)
        assert float(joined_tree["best"]) == 0.5
        assert int(joined_tree["arg"]) == 7

    def test_non_monotone_updates_discarded(self):
        # an "update" that would raise the state must be rejected by c
        xs = jnp.asarray([5.0, 9.0, 3.0, 7.0], dtype=jnp.float32)
        trace, s = semantics.successive_approximation(
            c=lambda x, s: x < s,
            s_prime=lambda x, s: x,
            xs=xs,
            s_init=jnp.float32(6.0),
        )
        assert np.asarray(trace).tolist() == [5.0, 5.0, 3.0, 3.0]
        assert float(s) == 3.0


# ---------------------------------------------------------------------------
# §4.5 separate task/state
# ---------------------------------------------------------------------------

class TestSeparateTaskState:
    @given(int_streams())
    @settings(max_examples=25, deadline=None)
    def test_f_is_state_independent_and_trace_folds(self, data):
        xs = jnp.asarray(data, dtype=jnp.int32)
        ys, trace, s = semantics.separate_task_state(
            f=lambda x: x * x, s=lambda y, st: st + y, xs=xs, s0=jnp.int32(0)
        )
        np.testing.assert_array_equal(np.asarray(ys), np.asarray(xs) ** 2)
        assert int(s) == int(jnp.sum(xs * xs))
        np.testing.assert_array_equal(
            np.asarray(trace), np.cumsum(np.asarray(xs, dtype=np.int64) ** 2).astype(np.int32)
        )

    def test_speedup_bound(self):
        assert patterns.SeparateTaskState.speedup_bound(100, 1) == 101
        assert patterns.SeparateTaskState.speedup_bound(10, 1) == 11
        assert patterns.SeparateTaskState.speedup_bound(5, 1) == 6
