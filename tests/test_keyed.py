"""Tests for the keyed windowed-state subsystem (`repro.keyed`).

The acceptance contract: keyed windowed outputs, late records, and the final
store state are **bit-exact** against the serial oracle
(:func:`repro.core.semantics.keyed_windows`) across mid-stream grow and
shrink for all three window kinds, at worker counts that do NOT divide
``num_slots``, on both the sort+segment-reduce hot path and the masked-scan
baseline.  Plus: slot-map invariants, Pallas kernel vs reference, the
autoscaler's feasibility clamp, and the supervisor's checkpoint-replay over
the keyed store.
"""

import shutil

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import semantics
from repro.keyed import (
    KeyedStore,
    KeyedWindowAdapter,
    KeyedWindowEngine,
    SlotMap,
    WindowSpec,
    hash_to_slot,
    plan_relocation,
    reduce_by_cell,
    synthetic_keyed_items,
)
from repro.runtime import (
    Autoscaler,
    BackpressureQueue,
    BoundedSource,
    Chunker,
    ConstantRate,
    FailurePlan,
    QueueDepthPolicy,
    StreamExecutor,
    Supervisor,
    pump,
)

NUM_SLOTS = 20  # degrees 3, 6, 7 do not divide this


def _triples(items):
    return [(int(r["key"]), int(r["value"]), int(r["ts"])) for r in items]


def _emissions(outs):
    return [
        tuple(int(x) for x in row)
        for o in outs
        for row in zip(
            *(o["emissions"][k] for k in ("key", "start", "end", "value",
                                          "count"))
        )
    ]


def _state_rows(state):
    return [
        tuple(int(x) for x in r)
        for r in zip(
            *(np.asarray(state[k]).tolist()
              for k in ("w_key", "w_start", "w_end", "w_value", "w_count"))
        )
    ]


def _spec_for(kind):
    if kind == "tumbling":
        return WindowSpec("tumbling", size=7, lateness=3, late_policy="side")
    if kind == "sliding":
        return WindowSpec("sliding", size=9, slide=4, lateness=3,
                          late_policy="side")
    return WindowSpec("session", gap=5, lateness=3, late_policy="side")


# ---------------------------------------------------------------------------
# slot map
# ---------------------------------------------------------------------------

class TestSlotMap:
    def test_default_table_reduces_to_block_on_divisors(self):
        sm = SlotMap(16, 4)
        np.testing.assert_array_equal(sm.table, np.arange(16) // 4)
        assert sm.counts().tolist() == [4, 4, 4, 4]

    def test_any_worker_count_is_valid_and_balanced(self):
        for n in range(1, NUM_SLOTS + 1):
            c = SlotMap(NUM_SLOTS, n).counts()
            assert c.sum() == NUM_SLOTS
            assert c.max() - c.min() <= 1

    def test_rebalance_is_minimal_and_balanced(self):
        sm = SlotMap(NUM_SLOTS, 6)
        sm2, moved = sm.rebalance(7)
        c = sm2.counts()
        assert c.max() - c.min() <= 1 and c.sum() == NUM_SLOTS
        np.testing.assert_array_equal(
            moved, np.flatnonzero(sm.table != sm2.table)
        )
        # keeping every surviving worker at/below quota means the moved set
        # cannot be smaller: only over-quota/departed slots moved
        again, moved_again = sm2.rebalance(7)
        assert len(moved_again) == 0

    @settings(max_examples=30)
    @given(st.integers(1, NUM_SLOTS), st.integers(1, NUM_SLOTS))
    def test_rebalance_chain_invariants(self, n_a, n_b):
        sm = SlotMap(NUM_SLOTS, n_a)
        sm2, moved = sm.rebalance(n_b)
        assert sm2.n_workers == n_b
        c = sm2.counts()
        assert c.max() - c.min() <= 1
        assert len(moved) == int(np.sum(sm.table != sm2.table))
        # a worker surviving the resize never receives its own slot back
        for s in moved:
            assert sm.table[s] != sm2.table[s]

    def test_handoff_volume_matches_rebalance(self):
        sm = SlotMap(NUM_SLOTS, 4)
        assert sm.handoff_volume(5) == len(sm.rebalance(5)[1])

    def test_bad_args_rejected(self):
        with pytest.raises(ValueError):
            SlotMap(8, 9)
        with pytest.raises(ValueError):
            SlotMap(8, 0)
        with pytest.raises(ValueError):
            SlotMap(8, 2).rebalance(9)


class TestStoreAndRelocation:
    def test_store_pytree_roundtrip_canonical(self):
        store = KeyedStore(NUM_SLOTS, 3)
        from repro.keyed import WindowState

        store.windows_of(5).append(WindowState(0, 7, 10, 2))
        store.windows_of(45).append(WindowState(7, 14, 3, 1))
        t = store.to_pytree()
        store2 = KeyedStore.from_pytree(t)
        t2 = store2.to_pytree()
        for k in t:
            np.testing.assert_array_equal(t[k], t2[k])
        assert store2.n_workers == 3

    def test_from_pytree_is_order_canonical(self):
        """Regression: serialization used to trust the array order, so a
        permuted (but logically identical) pytree rebuilt a store whose
        per-slot dict insertion order and per-key window-list order differed
        from a natively-built one.  from_pytree must canonicalize: any row
        permutation rebuilds the identical in-memory store."""
        from repro.keyed import WindowState

        store = KeyedStore(NUM_SLOTS, 3)
        # adversarial insertion: keys and window starts in decreasing order
        for key in (45, 5, 25, -7):
            for start in (21, 7, 0):
                store.windows_of(key).append(
                    WindowState(start, start + 7, key + start, 1)
                )
        t = store.to_pytree()
        rng = np.random.default_rng(0)
        perm = rng.permutation(len(t["w_key"]))
        shuffled = dict(
            t, **{k: t[k][perm]
                  for k in ("w_key", "w_start", "w_end", "w_value", "w_count")}
        )
        store2 = KeyedStore.from_pytree(shuffled)
        t2 = store2.to_pytree()
        for k in t:
            np.testing.assert_array_equal(t[k], t2[k], err_msg=k)
        # in-memory canonical form, not just canonical serialization:
        for slot_dict in store2.slots:
            assert list(slot_dict) == sorted(slot_dict)
            for wins in slot_dict.values():
                starts = [w.start for w in wins]
                assert starts == sorted(starts)

    def test_negative_keys_hash_consistently(self):
        """Scalar and array hashing must agree on negative keys (int64 keys
        are signed; a bare uint64 cast crashes on scalars but wraps on
        arrays) — and the engine must route them end to end."""
        for key in (-5, -1, 0, 7, -(2 ** 40)):
            scalar = int(hash_to_slot(key, NUM_SLOTS))
            arr = int(hash_to_slot(np.array([key], np.int64), NUM_SLOTS)[0])
            assert scalar == arr and 0 <= scalar < NUM_SLOTS
        from repro.keyed import keyed_stream

        items = keyed_stream(
            np.array([-3, 5, -3, -3, 5, -7], np.int64),
            np.arange(6, dtype=np.int64),
            np.arange(6, dtype=np.int64),
        )
        spec = WindowSpec("tumbling", size=4)
        eng = KeyedWindowEngine(spec, num_slots=NUM_SLOTS)
        out = eng.process_chunk(items)
        o_em, o_open, _ = semantics.keyed_windows(
            "tumbling", [(int(r["key"]), int(r["value"]), int(r["ts"]))
                         for r in items],
            size=4, watermark_every=6,
        )
        got = [tuple(int(x) for x in row)
               for row in zip(*(out["emissions"][k]
                                for k in ("key", "start", "end", "value",
                                          "count")))]
        assert got == o_em
        assert _state_rows(eng.snapshot()) == [tuple(t) for t in o_open]

    def test_plan_relocation_hash_collision_requeues(self):
        sessions = {0: 10, 1: 11, 2: 12}
        placements, requeued = plan_relocation(sessions, 2, policy="hash")
        assert len(placements) + len(requeued) == 3
        # every placement goes to the re-hashed slot
        for old, new in placements.items():
            assert new == int(hash_to_slot(sessions[old], 2))

    def test_plan_relocation_ondemand_keeps_and_compacts(self):
        placements, requeued = plan_relocation(
            {0: 5, 3: 6, 7: 7}, 4, policy="ondemand"
        )
        assert placements[0] == 0 and placements[3] == 3
        assert placements[7] in (1, 2) and not requeued


# ---------------------------------------------------------------------------
# kernels
# ---------------------------------------------------------------------------

class TestKernels:
    def _case(self, seed, rows, cells):
        rng = np.random.default_rng(seed)
        ids = rng.integers(0, cells, size=rows).astype(np.int32)
        vals = rng.integers(0, 100, size=(rows, 2)).astype(np.int32)
        return ids, vals

    def test_segment_and_masked_paths_agree(self):
        ids, vals = self._case(0, 57, 11)
        a = np.asarray(reduce_by_cell(ids, vals, 11, impl="segment"))
        b = np.asarray(reduce_by_cell(ids, vals, 11, impl="masked"))
        ref = np.zeros((11, 2), np.int64)
        np.add.at(ref, ids, vals)
        np.testing.assert_array_equal(a, ref)
        np.testing.assert_array_equal(b, ref)

    def test_pallas_interpret_matches_ref(self):
        import jax.numpy as jnp

        from repro.kernels import ref as kref
        from repro.kernels import segment_reduce as sr

        ids, vals = self._case(1, 37, 9)
        ids = np.sort(ids)
        got = sr.segment_sum(
            jnp.asarray(vals), jnp.asarray(ids), 9, interpret=True,
            block_rows=8,
        )
        want = kref.segment_sum_ref(jnp.asarray(vals), jnp.asarray(ids), 9)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

        rng = np.random.default_rng(2)
        table = rng.integers(0, 10, size=(6, 3)).astype(np.int32)
        tid = rng.integers(0, 6, size=17).astype(np.int32)
        rows = rng.integers(0, 5, size=(17, 3)).astype(np.int32)
        got = sr.scatter_add(
            jnp.asarray(table), jnp.asarray(tid), jnp.asarray(rows),
            interpret=True, block_rows=4,
        )
        want = kref.scatter_add_ref(
            jnp.asarray(table), jnp.asarray(tid), jnp.asarray(rows)
        )
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_ops_segment_sum_is_order_blind_and_sorted_path_matches(self):
        """ops.segment_sum must give ref-equal sums for UNSORTED ids on
        every dispatch path; the sorted-precondition fast path
        (ops.segment_sum_sorted / segment_sum_sorted) must agree once ids
        are sorted."""
        import jax.numpy as jnp

        from repro.kernels import ops
        from repro.kernels import ref as kref
        from repro.kernels import segment_reduce as sr

        ids, vals = self._case(3, 41, 7)  # deliberately unsorted
        want = np.asarray(
            kref.segment_sum_ref(jnp.asarray(vals), jnp.asarray(ids), 7)
        )
        got = np.asarray(ops.segment_sum(jnp.asarray(vals),
                                         jnp.asarray(ids), 7))
        np.testing.assert_array_equal(got, want)
        order = np.argsort(ids, kind="stable")
        got_sorted = np.asarray(
            sr.segment_sum_sorted(
                jnp.asarray(vals[order]), jnp.asarray(ids[order]), 7
            )
        )
        np.testing.assert_array_equal(got_sorted, want)
        got_ops = np.asarray(
            ops.segment_sum_sorted(
                jnp.asarray(vals[order]), jnp.asarray(ids[order]), 7
            )
        )
        np.testing.assert_array_equal(got_ops, want)

    def test_empty_and_bad_impl(self):
        out = np.asarray(
            reduce_by_cell(np.zeros(0, np.int32), np.zeros((0, 2), np.int32),
                           4)
        )
        np.testing.assert_array_equal(out, np.zeros((4, 2)))
        with pytest.raises(ValueError, match="impl"):
            reduce_by_cell(np.zeros(1, np.int32), np.zeros((1, 2), np.int32),
                           1, impl="nope")


# ---------------------------------------------------------------------------
# windows vs the serial oracle (the acceptance criterion)
# ---------------------------------------------------------------------------

class TestWindowsBitExact:
    CHUNK = 16

    def _run_executor(self, spec, items, schedule, impl, degree=2):
        ad = KeyedWindowAdapter(spec, num_slots=NUM_SLOTS, impl=impl)
        ex = StreamExecutor(ad, degree=degree, chunk_size=self.CHUNK)
        chunks = [
            items[i: i + self.CHUNK] for i in range(0, len(items), self.CHUNK)
        ]
        outs = ex.run(chunks, schedule=schedule)
        return ex, outs

    @pytest.mark.parametrize("kind", ["tumbling", "sliding", "session"])
    @pytest.mark.parametrize("impl", ["segment", "masked"])
    def test_grow_shrink_nondivisible_degrees_bit_exact(self, kind, impl):
        """Mid-stream grow (2->3->7) and shrink (7->2) at degrees that do
        NOT divide num_slots=20, bit-exact vs the serial fold."""
        spec = _spec_for(kind)
        items = synthetic_keyed_items(
            11 * self.CHUNK + 9, num_keys=9, disorder=6, seed=13
        )
        ex, outs = self._run_executor(
            spec, items, {2: 3, 5: 7, 8: 2}, impl
        )
        o_em, o_open, o_late = semantics.keyed_windows(
            kind, _triples(items), **spec.oracle_kwargs(self.CHUNK)
        )
        assert _emissions(outs) == o_em
        assert _state_rows(ex.state) == [tuple(t) for t in o_open]
        late_rows = [
            tuple(int(x) for x in row)
            for o in outs
            for row in zip(*(o["late"][k]
                             for k in ("key", "value", "ts", "start")))
        ]
        assert late_rows == o_late
        assert int(ex.state["late_count"]) == len(o_late)
        assert all(
            r.protocol == "S2-slotmap-handoff" for r in ex.metrics.resizes
        )

    @settings(max_examples=6)
    @given(
        st.sampled_from(["tumbling", "sliding", "session"]),
        st.integers(0, 10_000),
        st.integers(0, 10),
        st.sampled_from([(2, 5), (3, 7), (6, 4)]),
    )
    def test_property_random_streams_and_resizes(
        self, kind, seed, disorder, degrees
    ):
        """Property: random keyed streams with bounded disorder, random
        grow/shrink between non-divisor degrees, both hot paths agree with
        the oracle on emissions, late records, and final state."""
        spec = _spec_for(kind)
        items = synthetic_keyed_items(
            8 * self.CHUNK + 5, num_keys=7, disorder=disorder, seed=seed
        )
        d0, d1 = degrees
        o_em, o_open, o_late = semantics.keyed_windows(
            kind, _triples(items), **spec.oracle_kwargs(self.CHUNK)
        )
        for impl in ("segment", "masked"):
            ex, outs = self._run_executor(
                spec, items, {3: d1, 6: d0}, impl, degree=d0
            )
            assert _emissions(outs) == o_em
            assert _state_rows(ex.state) == [tuple(t) for t in o_open]

    def test_late_policy_drop_suppresses_side_output(self):
        spec = WindowSpec("tumbling", size=7, lateness=0, late_policy="drop")
        items = synthetic_keyed_items(64, num_keys=5, disorder=9, seed=5)
        ex, outs = self._run_executor(spec, items, None, "segment")
        assert all(len(o["late"]["key"]) == 0 for o in outs)
        # ...but the oracle-visible accounting is still kept in state
        o_em, _, o_late = semantics.keyed_windows(
            "tumbling", _triples(items), size=7,
            watermark_every=self.CHUNK, lateness=0, late_policy="drop",
        )
        assert len(o_late) > 0  # the stream really had late items
        assert int(ex.state["late_count"]) == len(o_late)
        assert _emissions(outs) == o_em

    def test_spec_validation(self):
        with pytest.raises(ValueError):
            WindowSpec("tumbling", size=0)
        with pytest.raises(ValueError):
            WindowSpec("sliding", size=8, slide=9)
        with pytest.raises(ValueError):
            WindowSpec("session", gap=0)
        with pytest.raises(ValueError):
            WindowSpec("hopping", size=4)
        with pytest.raises(ValueError):
            WindowSpec("tumbling", size=4, late_policy="retract")


# ---------------------------------------------------------------------------
# runtime: autoscaler clamp + live stream + supervisor/checkpoint coverage
# ---------------------------------------------------------------------------

class TestKeyedRuntime:
    def test_autoscaler_clamps_to_feasible_degrees(self):
        """Block ownership (16 slots): policy pressure toward an infeasible
        rung (3) must be clamped to the divisor ladder instead of raising
        in the executor (the pre-fix failure mode).  Uses the pattern's
        feasible_degrees hook through a stub executor (the real SPMD resize
        path is covered in tests/runtime_checks.py)."""
        import jax.numpy as jnp

        from repro.core import patterns
        from repro.runtime import MetricsBus, PartitionedAdapter

        pat = patterns.PartitionedState(
            f=lambda x, s: x + s,
            ns=lambda x, s: s + x,
            h=lambda x: (x.astype(jnp.int32) * 7) % 16,
            num_slots=16,
        )
        assert pat.feasible_degrees(6) == [1, 2, 4]
        ad = PartitionedAdapter(pat, jnp.zeros((16,), jnp.int32))
        assert ad.feasible_degrees(12, [1, 2, 3, 4, 6, 12]) == [1, 2, 4]

        class _StubExecutor:
            degree = 2
            chunk_size = 12
            chunks_done = 0
            metrics = MetricsBus()
            adapter = ad
            resized_to = None

            def feasible_degrees(self, candidates):
                return self.adapter.feasible_degrees(self.chunk_size,
                                                     candidates)

            def set_degree(self, n, reason=""):
                self.resized_to = self.degree = n
                return None

        class _Q:
            depth, high_watermark, low_watermark = 99, 8, 1

        ex = _StubExecutor()
        sc = Autoscaler(QueueDepthPolicy(), [1, 2, 3, 4], cooldown_chunks=0)
        d = sc.maybe_scale(ex, queue=_Q())
        assert d is not None and d.proposed == 4  # 3 skipped: not feasible
        assert ex.resized_to == 4
        # slotmap ownership makes every degree feasible — the clamp is a noop
        pat_sm = patterns.PartitionedState(
            f=pat.f, ns=pat.ns, h=pat.h, num_slots=16, ownership="slotmap"
        )
        assert pat_sm.feasible_degrees(6) == [1, 2, 3, 4, 5, 6]

    def test_keyed_adapter_feasible_degrees_are_all(self):
        ad = KeyedWindowAdapter(
            WindowSpec("tumbling", size=4), num_slots=NUM_SLOTS
        )
        ex = StreamExecutor(ad, degree=1, chunk_size=16)
        assert ex.feasible_degrees([1, 2, 3, 6, 7]) == [1, 2, 3, 6, 7]

    def test_live_stream_queue_autoscaler_bit_exact(self):
        """Source -> backpressure queue -> chunker -> executor with the
        queue-depth autoscaler resizing mid-stream: still oracle-exact."""
        spec = WindowSpec("tumbling", size=6, lateness=4, late_policy="side")
        CH = 16
        items = synthetic_keyed_items(12 * CH, num_keys=8, disorder=4, seed=11)
        ad = KeyedWindowAdapter(spec, num_slots=NUM_SLOTS, impl="segment")
        ex = StreamExecutor(ad, degree=2, chunk_size=CH)
        scaler = Autoscaler(
            QueueDepthPolicy(), candidates=[2, 3, 7], cooldown_chunks=1
        )
        src = BoundedSource(items)
        q = BackpressureQueue(capacity=6 * CH, high_watermark=3 * CH,
                              low_watermark=CH // 2)
        chunker = Chunker(CH)
        outs, pend, t = [], None, 0
        while not (src.exhausted and q.depth == 0):
            pend = pump(src, ConstantRate(3 * CH), q, t, pending=pend)
            q.observe()
            while chunker.ready(q):
                scaler.maybe_scale(ex, queue=q)
                outs.append(ex.process(chunker.next_chunk(q)))
            t += 1
        o_em, o_open, _ = semantics.keyed_windows(
            "tumbling", _triples(items), **spec.oracle_kwargs(CH)
        )
        assert _emissions(outs) == o_em
        assert _state_rows(ex.state) == [tuple(t) for t in o_open]
        assert ex.metrics.resizes, "backlog never triggered a resize"
        # the ladder's non-divisor rungs (3, 7) must actually be reachable
        assert any(r.n_new in (3, 7) for r in ex.metrics.resizes)

    def test_supervisor_checkpoint_replay_covers_keyed_store(self, tmp_path):
        """Failure -> rollback to checkpoint -> BoundedSource.seek replay:
        the keyed store round-trips through repro.checkpoint and the
        replayed run is bit-exact vs the oracle."""
        spec = WindowSpec("session", gap=6, lateness=5, late_policy="side")
        CH, NCH = 16, 6
        items = synthetic_keyed_items(CH * NCH, num_keys=7, disorder=5,
                                      seed=3)
        src = BoundedSource(items)

        def chunk_fn(i):
            src.seek(i * CH)
            return src.take(CH)

        ad = KeyedWindowAdapter(spec, num_slots=10, impl="segment")
        ex = StreamExecutor(ad, degree=3, chunk_size=CH)
        sup = Supervisor(
            ex, chunk_fn, num_chunks=NCH, ckpt_dir=str(tmp_path),
            ckpt_every=2, failure_plan=FailurePlan(fail_at=3, recover_after=2),
        )
        outs = sup.run()
        o_em, o_open, _ = semantics.keyed_windows(
            "session", _triples(items), **spec.oracle_kwargs(CH)
        )
        assert _emissions([outs[i] for i in range(NCH)]) == o_em
        assert _state_rows(ex.state) == [tuple(t) for t in o_open]
        kinds = [e.kind for e in sup.events]
        assert "failure" in kinds and "shrink" in kinds and "grow" in kinds
