"""Serve a small model with continuously-batched requests — the S2
partitioned-state session store in action (hash vs on-demand routing).

Run:  PYTHONPATH=src python examples/serve_lm.py [--policy ondemand|hash]
"""

import argparse
import time

import jax
import numpy as np

import repro.configs as configs
from repro.models import transformer as T
from repro.serving.engine import Request, ServingEngine


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="paper-synthetic")
    p.add_argument("--policy", default="ondemand", choices=["ondemand", "hash"])
    p.add_argument("--requests", type=int, default=12)
    p.add_argument("--slots", type=int, default=4)
    args = p.parse_args()

    cfg = configs.get(args.arch).reduced()
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    engine = ServingEngine(
        cfg, params, num_slots=args.slots, s_max=96, policy=args.policy
    )

    rng = np.random.default_rng(0)
    reqs = [
        Request(
            rid=i,
            prompt=rng.integers(0, 200, size=int(rng.integers(4, 12))).astype(np.int32),
            max_new_tokens=int(rng.integers(4, 10)),
        )
        for i in range(args.requests)
    ]
    t0 = time.perf_counter()
    for r in reqs:
        engine.submit(r)
    engine.run_to_completion()
    dt = time.perf_counter() - t0
    print(f"policy={args.policy}: {engine.tokens_out} tokens in {dt:.2f}s "
          f"({engine.tokens_out/dt:.1f} tok/s), {engine.steps} engine ticks")
    for r in reqs[:4]:
        print(f"  req {r.rid} (slot {r.slot}): prompt {len(r.prompt)} -> "
              f"{r.generated}")


if __name__ == "__main__":
    main()
