"""Quickstart: the paper's five state access patterns in 60 seconds.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.core import (
    AccumulatorState, PartitionedState, SeparateTaskState, SerialState,
    SuccessiveApproximationState, analytics, simulator,
)

mesh = jax.make_mesh((1,), ("workers",),
                     axis_types=(jax.sharding.AxisType.Auto,))
xs = jnp.arange(1, 33, dtype=jnp.int32)

# S1 serial: the state chains every task — no parallelism is sound.
serial = SerialState(f=lambda x, s: x + s, ns=lambda x, s: s + x)
ys, s = serial.run(mesh, "workers", xs, jnp.int32(0))
print(f"S1 serial:        final state {int(s)} (sum of 1..32)")

# S2 fully partitioned: the hash routes tasks to partition owners.
part = PartitionedState(
    f=lambda x, s: s, ns=lambda x, s: s + x, h=lambda x: x % 8, num_slots=8
)
ys, v = part.run(mesh, "workers", xs, jnp.zeros(8, jnp.int32))
print(f"S2 partitioned:   per-slot sums {v.tolist()}")

# S3 accumulator: assoc+comm fold, local accumulators + periodic flush.
acc = AccumulatorState(
    f=lambda x, view: view, g=lambda x: x, combine=lambda a, b: a + b,
    zero=lambda: jnp.int32(0),
)
ys, s = acc.run(mesh, "workers", xs, flush_every=8)
print(f"S3 accumulator:   final state {int(s)} (exact at any flush period)")

# S4 successive approximation: monotone best-so-far with stale local copies.
sa = SuccessiveApproximationState(
    c=lambda x, s: x < s, s_prime=lambda x, s: jnp.minimum(x, s),
)
trace, s = sa.run(mesh, "workers", xs.astype(jnp.float32), jnp.float32(1e9),
                  sync_every=8)
print(f"S4 successive:    global best {float(s)}")

# S5 separate task/state: f parallel, state commit serialized.
sep = SeparateTaskState(f=lambda x: x * x, s=lambda y, st: st + y)
ys, trace, s = sep.run(mesh, "workers", xs, jnp.int32(0))
print(f"S5 separate:      sum of squares {int(s)}; "
      f"speedup bound (t_f=100 t_s): {sep.speedup_bound(100, 1):.0f}x")

# the paper's analytic models + the calibrated farm simulator
r = simulator.simulate_accumulator(2048, 16, t_f=100.0, t_acc=1.0, flush_every=1)
ideal = analytics.ideal_completion(2048, 100.0, 1.0, 16)
print(f"simulator Fig.3:  completion {r.completion_time:.0f} vs ideal {ideal:.0f}")
