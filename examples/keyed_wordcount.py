"""Keyed word-count — the `repro.keyed` subsystem end to end.

The canonical keyed-window workload: a stream of (word, 1, ts) items,
counted per word in tumbling event-time windows, with out-of-order arrivals
handled by the watermark and an elastic worker pool rebalanced MID-STREAM
through the slot map — at worker counts that do not divide the slot count,
which block ownership could never run.

What it shows:

1. a live stream (source -> backpressure queue -> chunker);
2. the keyed window engine driven by `StreamExecutor`, hot path =
   sort-by-key + segment-reduce;
3. an autoscaler growing the farm under backlog, migrating only the
   reassigned slots (the §4.2 minimal handoff);
4. bit-exact agreement with the serial oracle from `repro.core.semantics`.

Run:  PYTHONPATH=src python examples/keyed_wordcount.py
"""

import numpy as np

from repro.core import semantics
from repro.keyed import KeyedWindowAdapter, WindowSpec, keyed_stream
from repro.runtime import (
    Autoscaler,
    BackpressureQueue,
    BoundedSource,
    Chunker,
    ConstantRate,
    QueueDepthPolicy,
    StreamExecutor,
    pump,
)

WORDS = ["state", "access", "pattern", "farm", "stream", "worker", "slot"]
CHUNK = 32
NUM_SLOTS = 20          # degrees 3 and 7 below do NOT divide 20
WINDOW = 16             # tumbling window length (event-time units)
LATENESS = 4            # out-of-orderness bound -> watermark delay


def make_stream(n=8 * CHUNK, seed=0):
    rng = np.random.default_rng(seed)
    word_ids = rng.integers(0, len(WORDS), size=n)
    # jitter exceeds the watermark's lateness bound, so a few stragglers
    # really do arrive after their window fired -> the side output
    jitter = LATENESS + 4
    ts = np.arange(n, dtype=np.int64) + rng.integers(-jitter, jitter + 1,
                                                     size=n)
    return keyed_stream(word_ids, np.ones(n, np.int64), ts)


def main() -> None:
    items = make_stream()
    spec = WindowSpec("tumbling", size=WINDOW, lateness=LATENESS,
                      late_policy="side")
    executor = StreamExecutor(
        KeyedWindowAdapter(spec, num_slots=NUM_SLOTS, impl="segment"),
        degree=2,
        chunk_size=CHUNK,
    )
    scaler = Autoscaler(QueueDepthPolicy(), candidates=[2, 3, 7],
                        cooldown_chunks=1)
    source = BoundedSource(items)
    queue = BackpressureQueue(capacity=6 * CHUNK, high_watermark=3 * CHUNK,
                              low_watermark=CHUNK // 2)
    chunker = Chunker(CHUNK)

    print(f"word-count over {len(items)} items, window={WINDOW}, "
          f"slots={NUM_SLOTS}, degrees={scaler.candidates}")
    outs, pending, t = [], None, 0
    while not (source.exhausted and queue.depth == 0):
        pending = pump(source, ConstantRate(3 * CHUNK), queue, t,
                       pending=pending)
        queue.observe()
        while chunker.ready(queue):
            scaler.maybe_scale(executor, queue=queue)
            outs.append(executor.process(chunker.next_chunk(queue)))
        t += 1

    for r in executor.metrics.resizes:
        print(f"  resize {r.n_old}->{r.n_new}: {r.protocol}, "
              f"{r.handoff_items}/{NUM_SLOTS} slots migrated")

    emitted = [
        (int(k), int(s), int(v))
        for o in outs
        for k, s, v in zip(o["emissions"]["key"], o["emissions"]["start"],
                           o["emissions"]["value"])
    ]
    print(f"  {len(emitted)} windows fired; sample:")
    for key, start, count in emitted[:5]:
        print(f"    [{start:4d},{start + WINDOW:4d}) {WORDS[key]!r:10} "
              f"x{count}")

    # the §4.2 contract: the elastic run equals the serial fold bit-exactly
    triples = [(int(r["key"]), int(r["value"]), int(r["ts"])) for r in items]
    oracle_em, _, oracle_late = semantics.keyed_windows(
        "tumbling", triples, **spec.oracle_kwargs(CHUNK)
    )
    assert [(k, s, v) for k, s, e, v, c in oracle_em] == emitted
    late_seen = sum(len(o["late"]["key"]) for o in outs)
    assert late_seen == len(oracle_late)
    print(f"  oracle check OK ({late_seen} late items routed to the side "
          f"output)")
    print("done.")


if __name__ == "__main__":
    main()
