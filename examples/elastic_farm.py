"""Elastic scaling demo — the paper's §4.x adaptivity protocols:

* the `repro.runtime` elastic streaming runtime: an autoscaled farm over a
  live bursty stream, resizing online through the §4.x protocols;
* S2 partitioned: grow the farm 4 -> 8 workers; state handoff volume per the
  block protocol; results unchanged.
* S3 accumulator: shrink 8 -> 4 by merging workers (s_i (+) s_j).
* S4 successive approximation: new workers join with the current global best.
* checkpoint-mediated mesh resize for a training state.

Run:  PYTHONPATH=src python examples/elastic_farm.py
(8 placeholder host devices are set before jax import — demo only.)
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 " + os.environ.get("XLA_FLAGS", "")
)

import shutil  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.checkpoint import checkpoint as ckpt  # noqa: E402
from repro.core import AccumulatorState, PartitionedState  # noqa: E402


def mesh(n):
    return jax.make_mesh((n,), ("workers",),
                         axis_types=(jax.sharding.AxisType.Auto,))


def runtime_demo() -> None:
    """The tentpole path: a live stream, a backpressure queue, and an
    autoscaler resizing the S2 farm online — outputs equal to the oracle."""
    import numpy as np

    from repro.core import PartitionedState
    from repro.runtime import (
        Autoscaler, BackpressureQueue, BoundedSource, BurstyRate, Chunker,
        PartitionedAdapter, QueueDepthPolicy, StreamExecutor, pump,
    )

    num_slots = 16
    pat = PartitionedState(
        f=lambda x, s: x * 2 + s, ns=lambda x, s: s + x,
        h=lambda x: (x.astype(jnp.int32) * 7) % num_slots, num_slots=num_slots,
    )
    data = np.arange(256, dtype=np.int32)
    ex = StreamExecutor(
        PartitionedAdapter(pat, jnp.zeros(num_slots, jnp.int32)),
        degree=2, chunk_size=16,
    )
    scaler = Autoscaler(QueueDepthPolicy(), candidates=[2, 4, 8],
                        cooldown_chunks=1)
    src = BoundedSource(data)
    q = BackpressureQueue(96, high_watermark=48, low_watermark=8)
    chunker = Chunker(16)
    outs, pend, t = [], None, 0
    while not (src.exhausted and q.depth == 0):
        pend = pump(src, BurstyRate(base=8, burst=64, period=4, duty=2), q, t,
                    pending=pend)
        q.observe()
        while chunker.ready(q):
            scaler.maybe_scale(ex, queue=q)
            outs.append(ex.process(chunker.next_chunk(q), queue_depth=q.depth))
        t += 1
    ys_ref, v_ref = pat.reference(jnp.asarray(data), jnp.zeros(num_slots, jnp.int32))
    assert (np.concatenate([np.asarray(o) for o in outs]) == np.asarray(ys_ref)).all()
    assert (np.asarray(ex.state) == np.asarray(v_ref)).all()
    edges = [(r.n_old, r.n_new, r.protocol) for r in ex.metrics.resizes]
    print(f"runtime: {len(outs)} chunks, resizes {edges}, "
          f"final degree {ex.degree} — outputs == serial oracle")


def main() -> None:
    runtime_demo()
    xs = jnp.arange(64, dtype=jnp.int32)
    pat = PartitionedState(
        f=lambda x, s: s, ns=lambda x, s: s + x, h=lambda x: x % 16,
        num_slots=16,
    )
    v0 = jnp.zeros(16, jnp.int32)

    # run on 4 workers, grow to 8 (paper §4.2 adaptivity)
    ys, v4 = pat.run(mesh(4), "workers", xs[:32], v0)
    moved = PartitionedState.handoff_volume(16, 4, 8)
    print(f"S2 grow 4->8: {moved}/16 slots change owner (block protocol)")
    v_res = PartitionedState.reshard(v4, 4, 8)  # value is placement-invariant
    from jax.sharding import NamedSharding, PartitionSpec as P

    m8 = mesh(8)
    v_res = jax.device_put(v_res, NamedSharding(m8, P("workers")))  # the handoff
    ys2, v8 = pat.run(m8, "workers", xs[32:], v_res)
    # oracle: one serial pass over the whole stream
    _, v_ref = pat.reference(xs, v0)
    assert (v8 == v_ref).all(), (v8, v_ref)
    print(f"   state after resize matches serial oracle: {v8.tolist()}")

    # S3: merge two workers' accumulators when shrinking
    acc = AccumulatorState(
        f=lambda x, s: s, g=lambda x: x, combine=lambda a, b: a + b,
        zero=lambda: jnp.int32(0),
    )
    merged = acc.merge_workers(jnp.int32(100), jnp.int32(23))
    print(f"S3 shrink: merged accumulator {int(merged)} (= s_i + s_j)")

    # checkpoint-mediated resize of a sharded training-ish state
    tmp = "/tmp/repro_elastic_ckpt"
    shutil.rmtree(tmp, ignore_errors=True)
    state = {"w": jnp.arange(32.0).reshape(8, 4)}
    ckpt.save(tmp, 1, state, metadata={"note": "resize demo"})
    from jax.sharding import NamedSharding, PartitionSpec as P

    new_mesh = mesh(8)
    shardings = {"w": NamedSharding(new_mesh, P("workers", None))}
    restored, _ = ckpt.restore(tmp, 1, state, sharding_tree=shardings)
    print(f"ckpt resize: restored onto 8-way mesh, sharding "
          f"{restored['w'].sharding.spec}, value ok="
          f"{bool((restored['w'] == state['w']).all())}")


if __name__ == "__main__":
    main()
