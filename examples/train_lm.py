"""End-to-end driver: train a ~100M-param LM for a few hundred steps on CPU,
with S3 gradient accumulation, S5 sharded AdamW, S4 best-loss tracking,
checkpoint/restart fault tolerance, and one simulated failure.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 200] [--fail-at 50]
"""

import argparse
import dataclasses
import shutil

import jax

import repro.configs as configs
from repro.data.pipeline import SyntheticLM
from repro.ft.driver import TrainLoop
from repro.launch.cells import CellKnobs
from repro.launch.sharding import ShardingRules
from repro.launch.steps import build_train_step
from repro.models import transformer as T
from repro.optim import adamw


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="minicpm-2b")
    p.add_argument("--steps", type=int, default=200)
    p.add_argument("--fail-at", type=int, default=50)
    p.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = p.parse_args()

    # a ~100M-param cut of the chosen family, CPU-sized
    base = configs.get(args.arch)
    cfg = dataclasses.replace(
        base.reduced(),
        name=base.name + "-100m",
        num_layers=4,
        d_model=512,
        num_heads=8,
        num_kv_heads=min(base.num_kv_heads or 8, 8),
        head_dim=64,
        d_ff=1536,
        vocab_size=32_768,
    )
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    n = T.count_params(params)
    print(f"arch {cfg.name}: {n/1e6:.1f}M params")

    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    knobs = CellKnobs(microbatches=2, remat=True, fsdp=False)
    rules = ShardingRules(mesh=mesh, dp_axes=("data",), fsdp_axis=None)
    opt_cfg = adamw.AdamWConfig(
        peak_lr=3e-3, warmup_steps=20, total_steps=args.steps,
        schedule="wsd" if "minicpm" in args.arch else "cosine",
    )
    step = jax.jit(build_train_step(cfg, rules, knobs, opt_cfg=opt_cfg),
                   donate_argnums=(0, 1))
    opt_state = adamw.init_state(params)
    data = SyntheticLM(vocab=cfg.padded_vocab, seq_len=128, batch=8,
                       microbatches=2, seed=0)

    shutil.rmtree(args.ckpt_dir, ignore_errors=True)
    loop = TrainLoop(
        train_step=step, data=data, ckpt_dir=args.ckpt_dir,
        ckpt_every=25, metric_flush_every=10,
        fail_at=args.fail_at if args.fail_at > 0 else None,
    )
    params, opt_state, best = loop.run(params, opt_state, args.steps)
    print(f"done: best loss {best.best:.4f} @ step {best.step}")


if __name__ == "__main__":
    main()
